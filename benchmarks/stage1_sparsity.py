"""Fig 10 + Fig 11: stage-1 sparsity-aware training -> accuracy/sparsity
Pareto -> deployed performance at iso-accuracy.

Four workload recipes (paper §VII-A), scaled to run in minutes:
  * AKD1000  — Tl1 activation regularization on a ReLU classifier,
               applied to the pre-trained baseline;
  * Speck    — synops-regularized training, deployed as IF spiking;
  * PilotNet — per-layer sigma-delta threshold targets (vs uniform);
  * S5       — one-shot magnitude pruning + fine-tune sweep.
Deployment numbers come from the neuromorphic simulator on the trained
weights (real activations -> real event counts).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import workloads as W
from repro.neuromorphic.network import SimLayer, SimNetwork
from repro.neuromorphic.platform import (akd1000_like, loihi2_like,
                                         speck_like)
from repro.neuromorphic.timestep import simulate
from repro.sparsity import (calibrate_thresholds, magnitude_prune_masks,
                            apply_masks, synops_loss, tl1_regularizer)
from repro.train.data import SyntheticDenoise, SyntheticImages


# ------------------------------------------------------------ tiny trainers

def _mlp_init(key, sizes):
    ps = []
    for i in range(len(sizes) - 1):
        k1, key = jax.random.split(key)
        ps.append(jax.random.normal(k1, (sizes[i], sizes[i + 1]))
                  / np.sqrt(sizes[i]))
    return ps


def _mlp_fwd(ps, x):
    acts = []
    h = x
    for i, w in enumerate(ps):
        h = h @ w
        if i < len(ps) - 1:
            h = jax.nn.relu(h)
            acts.append(h)
    return h, acts


def _train_mlp(loss_fn, ps, data_iter, steps, lr=3e-3):
    opt = [jax.tree.map(jnp.zeros_like, ps), jax.tree.map(jnp.zeros_like, ps)]

    @jax.jit
    def step(ps, m, v, batch, t):
        (l, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(ps, batch)
        m = jax.tree.map(lambda a, b: 0.9 * a + 0.1 * b, m, g)
        v = jax.tree.map(lambda a, b: 0.99 * a + 0.01 * b * b, v, g)
        ps = jax.tree.map(
            lambda p, mm, vv: p - lr * mm / (jnp.sqrt(vv) + 1e-8), ps, m, v)
        return ps, m, v, l, aux
    m, v = opt
    aux = {}
    for t in range(steps):
        ps, m, v, l, aux = step(ps, m, v, data_iter(t), t)
    return ps, aux


def _deploy_fc(ps, *, neuron_model="relu", thresholds=None,
               sends_deltas=False, masks=None):
    layers = []
    for i, w in enumerate(ps):
        wi = np.asarray(w, np.float32)
        if masks is not None:
            wi = wi * np.asarray(masks[i], np.float32)
        layers.append(SimLayer(
            name=f"fc{i}", kind="fc", weights=wi,
            neuron_model=neuron_model if i < len(ps) - 1 else
            ("sd_relu" if neuron_model == "sd_relu" else "relu"),
            threshold=(thresholds[i] if thresholds is not None else
                       (1.0 if neuron_model == "if" else 0.0)),
            sends_deltas=sends_deltas and i < len(ps) - 1))
    return SimNetwork(layers=layers, in_size=int(ps[0].shape[0]))


# ------------------------------------------------------------ experiments

def akd1000_tl1(quick=False) -> list[dict]:
    """Tl1 sweep on a pre-trained ReLU classifier (AKD1000 recipe)."""
    data = SyntheticImages(hw=8, channels=2, global_batch=64, seed=0)
    def batches(t):
        b = data.batch(t)
        return (jnp.asarray(b["x"].reshape(64, -1)), jnp.asarray(b["y"]))
    sizes = [128, 384, 384, 10]       # hidden layers carry the synops
    steps = 60 if quick else 200

    def ce(ps, batch, lam):
        x, y = batch
        logits, acts = _mlp_fwd(ps, x)
        l = jnp.mean(-jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])
        reg = tl1_regularizer(acts) if lam else 0.0
        return l + lam * reg, {"ce": l}

    ps0, _ = _train_mlp(functools.partial(ce, lam=0.0),
                        _mlp_init(jax.random.PRNGKey(0), sizes),
                        batches, steps)
    rows = []
    for lam in [0.0, 0.01, 0.03, 0.1, 0.3]:
        ps, _ = _train_mlp(functools.partial(ce, lam=lam), ps0, batches,
                           steps // 2)
        xb, yb = batches(999)
        logits, acts = _mlp_fwd(ps, xb)
        acc = float(jnp.mean(jnp.argmax(logits, -1) == yb))
        dens = float(np.mean([np.mean(np.asarray(a) > 0) for a in acts]))
        net = _deploy_fc(ps)
        # batched simulate() made deployment eval cheap: price 16 samples
        # instead of the seed's 4 for a steadier mean
        xs = np.asarray(xb[:16])
        r = simulate(net, np.maximum(xs, 0), akd1000_like())
        rows.append({"lam": lam, "acc": acc, "act_density": dens,
                     "time": r.time_per_step, "energy": r.energy_per_step,
                     "baseline": lam == 0.0})
    return rows


def speck_synops(quick=False) -> list[dict]:
    data = SyntheticImages(hw=16, channels=2, global_batch=64, seed=1)
    def batches(t):
        b = data.batch(t)
        return (jnp.asarray(b["x"].reshape(64, -1)), jnp.asarray(b["y"]))
    sizes = [512, 96, 10]
    steps = 60 if quick else 200
    fanouts = [sizes[i + 2] if i + 2 < len(sizes) else 1
               for i in range(len(sizes) - 2)]

    def ce(ps, batch, lam):
        x, y = batch
        logits, acts = _mlp_fwd(ps, x)
        l = jnp.mean(-jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])
        reg = synops_loss(acts, fanouts) if lam else 0.0
        return l + lam * reg, {"ce": l}

    rows = []
    for lam in [0.0, 0.03, 0.1, 0.3]:
        ps, _ = _train_mlp(functools.partial(ce, lam=lam),
                           _mlp_init(jax.random.PRNGKey(1), sizes),
                           batches, steps)
        xb, yb = batches(999)
        logits, acts = _mlp_fwd(ps, xb)
        acc = float(jnp.mean(jnp.argmax(logits, -1) == yb))
        dens = float(np.mean([np.mean(np.asarray(a) > 0) for a in acts]))
        net = _deploy_fc(ps, neuron_model="if")
        # longer spike-rate window (8 repeats of the sample, was 4): the
        # batched engine prices it at the same cost
        xs = np.tile(np.maximum(np.asarray(xb[:1]), 0) / 4.0, (8, 1))
        r = simulate(net, xs, speck_like())
        rows.append({"lam": lam, "acc": acc, "act_density": dens,
                     "time": r.time_per_step, "energy": r.energy_per_step,
                     "baseline": lam == 0.0})
    return rows


def pilotnet_thresholds(quick=False) -> list[dict]:
    """Uniform Σ-Δ threshold (baseline [46]) vs per-layer sparsity targets."""
    data = SyntheticDenoise(n_features=64, seq_len=24, global_batch=16,
                            seed=2)
    sizes = [64, 96, 320, 64]         # imbalanced widths (CNN-like taper)
    steps = 60 if quick else 200

    def mse(ps, batch):
        x, y = batch
        pred, _ = _mlp_fwd(ps, x)
        return jnp.mean((pred - y) ** 2), {}

    def batches(t):
        b = data.batch(t)
        return (jnp.asarray(b["noisy"].reshape(-1, 64)),
                jnp.asarray(b["clean"].reshape(-1, 64)))
    ps, _ = _train_mlp(mse, _mlp_init(jax.random.PRNGKey(2), sizes),
                       batches, steps)

    # temporal sequence for Σ-Δ: one sample's 24 frames
    b = data.batch(1234)
    seq = np.asarray(b["noisy"][0], np.float32)          # (24, 64)
    clean = np.asarray(b["clean"][0], np.float32)

    # per-layer activation deltas from a reference run
    h = jnp.asarray(seq)
    deltas = []
    for i, w in enumerate(ps[:-1]):
        h = jax.nn.relu(h @ w)
        deltas.append(np.diff(np.asarray(h), axis=0).reshape(-1))

    rows = []
    uni = calibrate_thresholds([np.concatenate(deltas)], 0.7)[0]
    # per-layer targets: equalize each layer's DOWNSTREAM synops
    # (messages_i x fanout_i) — the M0 neurocore-aware quantity — at the
    # same total message budget as the uniform setting
    widths = np.array(sizes[1:-1], float)          # emitting layers
    fanout = np.array(sizes[2:], float)
    budget = 0.3 * float(np.sum(widths))           # total messages @ s=0.7
    w_inv = 1.0 / fanout
    dens = budget * w_inv / np.sum(widths * w_inv)
    tgt = np.clip(1.0 - dens, 0.05, 0.98)
    per = calibrate_thresholds(deltas, [float(t) for t in tgt])
    for name, thetas in [("uniform-baseline", [uni] * (len(sizes) - 1)),
                         ("per-layer-targets", per + [per[-1]])]:
        thetas = list(thetas)[:len(sizes) - 1] + [1e-6]
        net = _deploy_fc(ps, neuron_model="sd_relu", thresholds=thetas,
                         sends_deltas=True)
        r = simulate(net, seq, loihi2_like())
        mse_v = float(np.mean((r.outputs - clean) ** 2))
        rows.append({"setting": name, "mse": mse_v,
                     "time": r.time_per_step, "energy": r.energy_per_step,
                     "imbalance": r.metrics.synops.imbalance,
                     "baseline": name == "uniform-baseline"})
    return rows


def s5_pruning(quick=False) -> list[dict]:
    data = SyntheticDenoise(n_features=64, seq_len=24, global_batch=16,
                            seed=3)
    sizes = [64, 128, 128, 64]
    steps = 60 if quick else 200

    def batches(t):
        b = data.batch(t)
        return (jnp.asarray(b["noisy"].reshape(-1, 64)),
                jnp.asarray(b["clean"].reshape(-1, 64)))

    def mse(ps, batch, masks=None):
        x, y = batch
        pz = ps if masks is None else [w * m for w, m in zip(ps, masks)]
        pred, _ = _mlp_fwd(pz, x)
        return jnp.mean((pred - y) ** 2), {}

    ps, _ = _train_mlp(mse, _mlp_init(jax.random.PRNGKey(3), sizes),
                       batches, steps)
    rows = []
    for s in [0.0, 0.2, 0.4, 0.6, 0.8]:
        masks = jax.tree.leaves(magnitude_prune_masks(
            {f"w{i}": w for i, w in enumerate(ps)}, s))
        tuned, _ = _train_mlp(functools.partial(mse, masks=masks), ps,
                              batches, steps // 3)
        tuned = [w * m for w, m in zip(tuned, masks)]
        xb, yb = batches(999)
        pred, _ = _mlp_fwd(tuned, xb)
        mse_v = float(jnp.mean((pred - yb) ** 2))
        net = _deploy_fc([np.asarray(w) for w in tuned],
                         neuron_model="ssm")
        b = data.batch(1234)
        r = simulate(net, np.asarray(b["noisy"][0]), loihi2_like())
        rows.append({"sparsity": s, "mse": mse_v, "time": r.time_per_step,
                     "energy": r.energy_per_step, "baseline": s == 0.0,
                     "params": ps, "masks": masks, "tuned": tuned})
    return rows


def _iso_speedup(rows, *, acc_key="acc", higher_better=True,
                 tol=0.02):
    base = next(r for r in rows if r["baseline"])
    ok = [r for r in rows if not r["baseline"] and (
        r[acc_key] >= base[acc_key] - tol if higher_better
        else r[acc_key] <= base[acc_key] * (1 + tol) + 1e-6)]
    if not ok:
        return None, base, None
    best = min(ok, key=lambda r: r["time"])
    return (base["time"] / best["time"], base,
            {**best, "energy_gain": base["energy"] / best["energy"]})


def run(quick: bool = False) -> dict:
    out = {}
    out["akd1000"] = [
        {k: v for k, v in r.items()} for r in akd1000_tl1(quick)]
    out["speck"] = speck_synops(quick)
    out["pilotnet"] = pilotnet_thresholds(quick)
    s5_rows = s5_pruning(quick)
    out["s5"] = [{k: v for k, v in r.items()
                  if k not in ("params", "masks", "tuned")} for r in s5_rows]
    out["_s5_full"] = s5_rows          # used by stage2
    speed = {}
    speed["akd1000"] = _iso_speedup(out["akd1000"])[0]
    speed["speck"] = _iso_speedup(out["speck"])[0]
    pb = out["pilotnet"]
    speed["pilotnet"] = pb[0]["time"] / pb[1]["time"]
    speed["s5"] = _iso_speedup(out["s5"], acc_key="mse",
                               higher_better=False, tol=0.3)[0]
    out["iso_speedups"] = speed
    return out


def report(res: dict) -> str:
    lines = ["## Fig 10/11 — stage-1 sparsity training"]
    for wl in ("akd1000", "speck", "pilotnet", "s5"):
        s = res["iso_speedups"][wl]
        lines.append(f"  {wl:9s} iso-accuracy deployed speedup: "
                     f"{s if s is None else round(s, 2)}x "
                     f"(paper: akd 4.29x, speck 1.01x, pilot 2.23x, "
                     f"s5 1.74x)")
    return "\n".join(lines)
