"""Iso-accuracy loop (paper §VII headline, closed end-to-end): floorline-
guided sparsity-aware training -> trained sparsity profile -> evolutionary
mapping search -> accuracy-vs-time/energy front.

This arm is the tentpole wiring: a dense baseline is trained first, the
floorline model prices its deployment and weights the per-layer
regularizers (:meth:`SparseTrainer.floorline_weights`), then the guided
recipes (Tl1 activation regularization, one-shot magnitude prune + masked
fine-tune) are trained and each trained network is fed through the
evolutionary mapping search.  Every config lands on a (accuracy, knee
time, knee energy) row; the headline check is the paper's: the best
trained-sparsity config must beat the dense baseline's knee time at
matched accuracy (within 1%).

The best config's :class:`~repro.sparsity.profile.SparsityProfile` is then
injected into a compiled model-zoo arch (``compile_network(act_density=
profile)``), replacing the synthetic density schedules of
``benchmarks/act_schedules.py`` with measured, trained densities.

Appends an ``iso_accuracy`` section to ``BENCH_search.json`` (other
sections survive, :func:`benchmarks._bench_io.merge_write_json`).
"""

from __future__ import annotations

import os

import numpy as np

from benchmarks import workloads as W
from benchmarks._bench_io import merge_write_json
from repro.core.partitioner import SimEvaluator
from repro.core.search import evolutionary_search
from repro.neuromorphic.platform import loihi2_like
from repro.neuromorphic.timestep import simulate
from repro.train import SparseTrainConfig, SparseTrainer

BENCH_PATH = "BENCH_search.json"

SIZES = (128, 192, 128, 10)          # images task: sizes[0] = 2*8^2
ACC_TOL = 0.01                       # "matched accuracy" band (paper: iso)


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _probe_xs(trainer: SparseTrainer, steps: int) -> np.ndarray:
    """Shared held-out input stream (every config prices the same data)."""
    b = trainer.data.batch(10_999)
    x = b["x"].reshape(len(b["y"]), -1)[:steps]
    return np.maximum(np.asarray(x, np.float32), 0.0)


def _search_knee(net, xs, chip, *, pop: int, gens: int):
    """Knee-point (time, energy) of a short evolutionary mapping search."""
    ev = SimEvaluator(net, xs, chip)
    res = evolutionary_search(net, chip, ev, population_size=pop,
                              generations=gens, seed=0)
    knee = res.knee()
    rep = knee[1] if knee is not None else res.report
    return (float(rep.time_per_step), float(rep.energy_per_step),
            int(res.n_evals))


def run(quick: bool = False) -> dict:
    smoke = _smoke()
    steps = 40 if smoke else (80 if quick else 200)
    ft = 15 if smoke else (30 if quick else 60)
    pop = 10 if smoke else (12 if quick else 20)
    gens = 3 if smoke else (5 if quick else 10)
    T = 4 if smoke else 8
    lams = [0.05] if smoke else [0.02, 0.05, 0.15]
    chip = loihi2_like()

    # 1. dense baseline + floorline guidance read off its deployment
    base = SparseTrainer(
        SparseTrainConfig(sizes=SIZES, steps=steps, seed=0)).train()
    guide = base.floorline_weights(chip, probe_steps=T)

    # 2. guided sparsity recipes (§VII-A): Tl1 sweep + prune/fine-tune
    trainers = [("dense", base)]
    for lam in lams:
        cfg = SparseTrainConfig(sizes=SIZES, steps=steps, lam=lam,
                                reg="tl1", seed=0)
        trainers.append((f"tl1[{lam}]",
                         SparseTrainer(cfg, layer_weights=guide).train()))
    cfg = SparseTrainConfig(sizes=SIZES, steps=steps, lam=lams[0],
                            reg="tl1", prune_sparsity=0.5,
                            finetune_steps=ft, seed=0)
    trainers.append((f"tl1[{lams[0]}]+prune0.5",
                     SparseTrainer(cfg, layer_weights=guide).train()))

    # 3. every trained network through the mapping search -> front rows
    xs = _probe_xs(base, T)
    rows = []
    profiles = {}
    for name, tr in trainers:
        met = tr.eval_metrics()
        profile = tr.extract_profile(meta={"config": name})
        profiles[name] = profile
        t, e, n_evals = _search_knee(tr.deploy(), xs, chip,
                                     pop=pop, gens=gens)
        rows.append({
            "config": name, "baseline": name == "dense",
            "acc": met["acc"], "act_density": met["act_density"],
            "weight_density": float(np.mean(profile.weight_density)),
            "time": t, "energy": e, "n_evals": n_evals,
            "profile_act_density": [float(d) for d in profile.act_density],
        })

    # 4. the paper's iso-accuracy verdict
    base_row = rows[0]
    ok = [r for r in rows if not r["baseline"]
          and r["acc"] >= base_row["acc"] - ACC_TOL]
    best = min(ok, key=lambda r: r["time"]) if ok else None
    out = {
        "rows": rows,
        "guidance_weights": [float(w) for w in guide],
        "iso_ok": bool(best is not None
                       and best["time"] < base_row["time"]),
        "iso_speedup": (None if best is None
                        else base_row["time"] / best["time"]),
        "iso_energy_gain": (None if best is None
                            else base_row["energy"] / best["energy"]),
        "best_config": None if best is None else best["config"],
    }

    # 5. inject the winning profile into a compiled arch: trained measured
    # densities replace the synthetic schedules of act_schedules.py
    winner = profiles[(best or base_row)["config"]]
    arch = W.MODEL_ZOO_ARCHS[0]
    mean_d = float(np.mean(winner.act_density))
    comp_syn, chip2 = W.model_zoo(arch, act_density=mean_d, seed=1)
    comp_tr, _ = W.model_zoo(arch, act_density=winner, seed=1)
    xs2 = comp_syn.inputs(T, seed=2)
    r_syn = simulate(comp_syn.net, xs2, chip2)
    r_tr = simulate(comp_tr.net, xs2, chip2)
    out["profile_injection"] = {
        "arch": arch, "mean_density": mean_d,
        "synthetic_time": float(r_syn.time_per_step),
        "trained_profile_time": float(r_tr.time_per_step),
        "time_ratio": float(r_tr.time_per_step / r_syn.time_per_step),
    }

    merge_write_json(BENCH_PATH, {"iso_accuracy": out})
    return out


def report(res: dict) -> str:
    lines = ["## iso-accuracy loop — train -> profile -> mapping search"]
    gw = ", ".join(f"{w:.2f}" for w in res["guidance_weights"])
    lines.append(f"  floorline layer weights: [{gw}]")
    for r in res["rows"]:
        tag = "base" if r["baseline"] else "    "
        lines.append(
            f"  {tag} {r['config']:18s} acc {r['acc']:.3f}  "
            f"act-d {r['act_density']:.3f}  w-d {r['weight_density']:.2f}  "
            f"knee time {r['time']:8.1f}  energy {r['energy']:10.1f}")
    sp = res["iso_speedup"]
    lines.append(
        f"  iso-accuracy (±{ACC_TOL:.0%}) knee speedup: "
        f"{sp if sp is None else round(sp, 2)}x "
        f"[{res['best_config']}]  ok={res['iso_ok']}")
    pi = res["profile_injection"]
    lines.append(
        f"  profile->compiled-arch injection ({pi['arch']}): trained/"
        f"synthetic time ratio {pi['time_ratio']:.3f} "
        f"at mean density {pi['mean_density']:.3f}")
    return "\n".join(lines)
