"""Fig 8: traffic bottleneck under high utilization — ordered vs strided
neurocore mapping.

Claim: with many cores per layer, same-layer cores placed contiguously
(ordered) congest shared routers; strided placement spreads them across
router paths and improves time/energy in every configuration without
raising the floor.
"""

from __future__ import annotations

import numpy as np

from benchmarks import workloads as W
from repro.neuromorphic.noc import ordered_mapping, strided_mapping
from repro.neuromorphic.partition import Partition, minimal_partition
from repro.neuromorphic.timestep import simulate

SIZES = (64, 256, 256, 256, 64)


def run(quick: bool = False) -> dict:
    # batched engine: longer windows are ~free -> tighter per-config means
    steps = 3 if quick else 10
    rows = []
    for tot in (0.8, 0.5, 0.2):
        net, prof = W.s5_programmed(
            SIZES, weight_densities=[1.0] * (len(SIZES) - 1),
            act_densities=W.schedule("uniform", len(SIZES) - 1, tot),
            seed=1)
        xs = W.sim_inputs(net, tot, steps, seed=2)
        base = minimal_partition(net, prof)
        part = Partition(tuple(min(c * 8, 20) for c in base.cores))
        from repro.neuromorphic import timestep
        pre = (net.run_batch(xs)     # one functional run, two mappings
               if timestep.DEFAULT_ENGINE == "batched" else None)
        r_ord = simulate(net, xs, prof, part, ordered_mapping(part, prof),
                         precomputed=pre)
        r_str = simulate(net, xs, prof, part, strided_mapping(part, prof),
                         precomputed=pre)
        rows.append({
            "density": tot, "cores": int(sum(part.cores)),
            "ordered_time": r_ord.time_per_step,
            "strided_time": r_str.time_per_step,
            "ordered_link": r_ord.max_link_load,
            "strided_link": r_str.max_link_load,
            "speedup": r_ord.time_per_step / r_str.time_per_step,
            "ordered_bottleneck": r_ord.bottleneck_stage,
        })
    return {"rows": rows,
            "always_helps": all(r["speedup"] >= 0.999 for r in rows)}


def report(res: dict) -> str:
    lines = ["## Fig 8 — ordered vs strided mapping (traffic bound)"]
    for r in res["rows"]:
        lines.append(
            f"  density={r['density']:.1f} cores={r['cores']:<3d} "
            f"ordered={r['ordered_time']:9.1f} ({r['ordered_bottleneck']}) "
            f"strided={r['strided_time']:9.1f} -> {r['speedup']:.2f}x; "
            f"max link load {r['ordered_link']:.0f} -> {r['strided_link']:.0f}")
    lines.append(f"  strided never hurts: {res['always_helps']} "
                 "(paper: improves all cases)")
    return "\n".join(lines)
