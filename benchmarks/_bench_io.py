"""Shared helpers for the benchmark JSON artifacts.

The BENCH_*.json files accumulate sections from several independent
benchmark modules (and from partial ``--only`` runs), so writers must
merge-update their own sections instead of truncating everyone else's.
:func:`merge_write_json` is the single write path: read-or-empty, update,
atomic replace (a crashed run never leaves a half-written artifact).
"""

from __future__ import annotations

import json
import os
import tempfile


def merge_write_json(path: str, updates: dict) -> dict:
    """Merge ``updates`` into the JSON object at ``path`` atomically.

    Top-level keys in ``updates`` replace their previous values wholesale
    (a section is one experiment's output — partial intra-section merges
    would mix runs); everything else already recorded survives.  A
    missing or corrupt file starts from ``{}``.  Returns the merged dict.
    """
    try:
        with open(path) as f:
            merged = json.load(f)
        if not isinstance(merged, dict):
            merged = {}
    except (OSError, json.JSONDecodeError):
        merged = {}
    merged.update(updates)
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(merged, f, indent=1, default=float)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return merged
