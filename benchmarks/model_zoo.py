"""Model-zoo arm: compiled real-model workloads through the full pricing
stack — floorline fit, compute-backend parity/speed, and a short
evolutionary mapping search per compiled arch.

The fc/conv microbenchmarks characterize the simulator; this arm prices the
*workloads the paper argues about*: real architecture configs compiled by
:mod:`repro.neuromorphic.frontend` (attention / SSD / MoE blocks with exact
per-token counter maps).  Appends a ``model_zoo`` section to
``BENCH_sim.json``:

* per-arch rows — layer/param/MAC arithmetic of the compiled network,
  floorline fit over programmed activation densities, best time/step from a
  short evolutionary search, and the dense/event counter-parity witness;
* smoke mode (``REPRO_BENCH_SMOKE=1``) prices one arch with a 2-generation
  search so the CI suite stays fast; the full run covers one arch per
  family and adds the device-engine search + three-backend pricing parity.
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks import workloads as W
from repro.core.floorline import WorkloadPoint, fit_floorline
from repro.core.partitioner import SimEvaluator
from repro.core.search import evolutionary_search
from repro.neuromorphic import minimal_partition, simulate, simulate_population
from repro.neuromorphic.noc import ordered_mapping, strided_mapping

BENCH_PATH = "BENCH_sim.json"

FLOOR_DENSITIES = (1.0, 0.5, 0.2, 0.05)


def _smoke() -> bool:
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def _floorline_points(arch_id: str, steps: int) -> list[WorkloadPoint]:
    pts = []
    for dens in FLOOR_DENSITIES:
        compiled, prof = W.model_zoo(arch_id, act_density=dens, seed=1)
        xs = compiled.inputs(steps, seed=2)
        r = simulate(compiled.net, xs, prof)
        pts.append(WorkloadPoint(max_synops=r.max_synops, max_acts=r.max_acts,
                                 time=r.time_per_step,
                                 energy=r.energy_per_step,
                                 label=f"{arch_id}/{dens}"))
    return pts


def _backend_parity(compiled, prof, xs) -> dict:
    """Exact-counter witness: dense vs event totals must be identical."""
    _, cnt_d = compiled.net.run_batch(xs, compute="dense")
    _, cnt_e = compiled.net.run_batch(xs, compute="event")
    tot_d = sum(float(c.macs.sum()) for c in cnt_d)
    tot_e = sum(float(c.macs.sum()) for c in cnt_e)
    return {"macs_dense": tot_d, "macs_event": tot_e,
            "identical": tot_d == tot_e and all(
                np.array_equal(a.macs, b.macs)
                for a, b in zip(cnt_d, cnt_e))}


def _pricing_parity(compiled, prof, xs) -> dict:
    """numpy/vmap/device population backends price identically."""
    p0 = minimal_partition(compiled.net, prof)
    cands = [(p0, ordered_mapping(p0, prof)), (p0, strided_mapping(p0, prof))]
    rows = {}
    for backend in ("numpy", "vmap", "device"):
        t0 = time.perf_counter()
        reps = simulate_population(compiled.net, xs, prof, cands,
                                   backend=backend)
        rows[backend] = {"secs": time.perf_counter() - t0,
                         "time_per_step": [float(r.time_per_step)
                                           for r in reps]}
    base = rows["numpy"]["time_per_step"]
    rows["max_rel_err"] = max(
        abs(a - b) / abs(b)
        for k in ("vmap", "device")
        for a, b in zip(rows[k]["time_per_step"], base))
    return rows


def _one_arch(arch_id: str, *, steps: int, generations: int,
              pop: int, full: bool) -> dict:
    compiled, prof = W.model_zoo(arch_id)
    xs = compiled.inputs(steps, seed=3)
    row: dict = {
        "arch": arch_id,
        "family": compiled.family,
        "n_layers": len(compiled.net.layers),
        "param_nnz": compiled.param_layer_nnz(),
        "macs_per_token": compiled.macs_per_token(),
        "n_attn_sites": len(compiled.attn_specs),
    }
    pts = _floorline_points(arch_id, steps)
    model = fit_floorline(pts)
    row["floorline"] = {"mem_latency": model.mem_latency,
                        "act_latency": model.act_latency, "t0": model.t0,
                        "n_points": len(pts)}
    row["backend_parity"] = _backend_parity(compiled, prof, xs)

    ev = SimEvaluator(compiled.net, xs, prof, population_backend="vmap")
    t0 = time.perf_counter()
    res = evolutionary_search(compiled.net, prof, ev,
                              population_size=pop, generations=generations,
                              seed=0)
    row["search"] = {"engine": "numpy", "generations": generations,
                     "population": pop, "secs": time.perf_counter() - t0,
                     "n_evals": res.n_evals,
                     "seed_best_time": res.seed_best_time,
                     "best_time_per_step": res.report.time_per_step,
                     "bottleneck": res.report.bottleneck_stage}
    if full:
        row["pricing_parity"] = _pricing_parity(compiled, prof, xs)
        ev_d = SimEvaluator(compiled.net, xs, prof)
        t0 = time.perf_counter()
        res_d = evolutionary_search(compiled.net, prof, ev_d,
                                    population_size=pop,
                                    generations=generations, seed=0,
                                    engine="device")
        row["search_device"] = {
            "secs": time.perf_counter() - t0,
            "best_time_per_step": res_d.report.time_per_step}
    return row


def run(quick: bool = False, arch: str | None = None) -> dict:
    smoke = _smoke()
    archs = ([arch] if arch else
             list(W.MODEL_ZOO_ARCHS[:1] if smoke else W.MODEL_ZOO_ARCHS))
    steps = 4 if quick else 8
    generations = 2 if (quick or smoke) else 6
    pop = 8 if (quick or smoke) else 16
    rows = [_one_arch(a, steps=steps, generations=generations, pop=pop,
                      full=not smoke) for a in archs]
    res = {"rows": rows, "smoke": smoke}

    from benchmarks._bench_io import merge_write_json
    merge_write_json(BENCH_PATH, {"model_zoo": res})
    return res


def report(res: dict) -> str:
    lines = ["## model zoo — compiled real-model workloads"]
    for r in res["rows"]:
        s = r["search"]
        gain = r["search"].get("seed_best_time", 0.0)
        gain = (gain / s["best_time_per_step"]) if s["best_time_per_step"] else 1.0
        lines.append(
            f"  {r['arch']:16s} [{r['family']}] {r['n_layers']} layers, "
            f"{r['macs_per_token']} MACs/token: search {s['generations']}g -> "
            f"time/step {s['best_time_per_step']:.0f} "
            f"({gain:.2f}x vs seed pop), counters "
            f"{'identical' if r['backend_parity']['identical'] else 'DIVERGED'}"
            f" across compute backends")
    return "\n".join(lines)
