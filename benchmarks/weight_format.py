"""Fig 4: dense vs sparse weight-format crossover.

Claim: sparse formatting only beats dense above a weight-sparsity
crossover, which is HIGH for CNNs (~0.7 — small per-message kernel fetches
make decode overhead dominate) and LOW for linear nets (~0.2).
"""

from __future__ import annotations

import numpy as np

from benchmarks import workloads as W
from repro.neuromorphic.timestep import simulate

WDS = [1.0, 0.9, 0.8, 0.7, 0.6, 0.5, 0.4, 0.3, 0.2, 0.1]


def _sweep(builder, fmt, steps, **kw):
    ts = []
    for wd in WDS:
        net, prof = builder(weight_density=wd, weight_format=fmt, **kw)
        xs = W.sim_inputs(net, 0.5, steps, seed=2)
        ts.append(simulate(net, xs, prof).time_per_step)
    return ts


def _crossover(dense, sparse):
    for wd, td, tsp in zip(WDS, dense, sparse):
        if tsp < td:
            return 1.0 - wd            # sparsity where sparse starts winning
    return None


def run(quick: bool = False) -> dict:
    steps = 3 if quick else 5
    out = {}
    for name, builder, kw in [
            ("pilotnet-cnn", W.pilotnet_sim, {}),
            ("s5-linear", W.s5_sim, {})]:
        dense = _sweep(builder, "dense", steps, seed=1, **kw)
        sparse = _sweep(builder, "sparse", steps, seed=1, **kw)
        out[name] = {"wd": WDS, "dense": dense, "sparse": sparse,
                     "crossover_sparsity": _crossover(dense, sparse)}
    return out


def report(res: dict) -> str:
    lines = ["## Fig 4 — sparse weight-format crossover"]
    for name, r in res.items():
        lines.append(f"  {name:14s} sparse format wins above "
                     f"{r['crossover_sparsity']} weight sparsity "
                     f"(paper: CNN ~0.7, linear ~0.2)")
    return "\n".join(lines)
