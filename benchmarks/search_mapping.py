"""Greedy §VI-B walk vs evolutionary mapping search, head to head.

Both optimizers price candidates through one :class:`SimEvaluator` kind
(same pricing cache, same evaluation counting), so the comparison is
iso-evaluation: with a total budget of B candidate pricings, the greedy
walk converges after its own ``greedy_evals`` (it cannot spend more — that
is its failure mode), while the evolutionary pipeline spends the same
``greedy_evals`` producing its floorline-informed seeds and the remaining
``B - greedy_evals`` on population generations.  A cold-start evolutionary
run (no greedy seeds) gets the full budget B for reference.

Writes ``BENCH_search.json`` at the repo root: best time/energy per
optimizer at iso-evaluations, the evolutionary front's knee point, plus two
throughput microbenchmarks:

* ``pricing`` — evals/s of the three population-pricing backends
  (``numpy`` / ``vmap`` / ``device``) repricing one fixed population;
* ``generation`` — FULL-generation throughput of the three search engines
  at population >= 256: the host loop pricing through numpy, the host loop
  pricing through the jitted vmap backend ("vmap-pricing-only" — mutation,
  selection and survival still per-offspring Python), and the
  device-resident engine whose whole generation step is one jitted program
  (``repro.core.search``, ``engine="device"``).  The headline number is
  ``device_speedup_vs_vmap``.

Plus the multi-device section (``sharded``): the island-model
``engine="sharded"`` vs the single-device engine at EQUAL total
population, across every visible device.  On CPU, run with ``--devices N``
(applied before jax initializes — see ``repro.launch.mesh``) to shard over
``N`` forced host devices; the headline is ``sharded_speedup_vs_device``.

Sections merge-update ``BENCH_search.json`` (other sections survive).
"""

from __future__ import annotations

import os
import sys
import time

if __name__ == "__main__":
    # --devices must rewrite XLA_FLAGS before anything imports jax; the
    # argparse pass below keeps the flag for --help and validation
    from repro.launch.mesh import apply_devices_flag
    apply_devices_flag(sys.argv[1:])

from benchmarks import workloads as W
from benchmarks._bench_io import merge_write_json
from repro.core.partitioner import SimEvaluator, optimize_partitioning
from repro.core.search import decode, evolutionary_search, seeded_population
from repro.neuromorphic.noc import ordered_mapping
from repro.neuromorphic.partition import minimal_partition
from repro.neuromorphic.timestep import precompute_pricing, simulate_population

BENCH_PATH = "BENCH_search.json"


def _pricing_throughput(net, xs, prof, *, pop: int, repeats: int,
                        seed: int = 0) -> dict:
    """evals/s of the two population-pricing backends on one fixed
    population (>= 64 candidates unless the workload cannot seed that many),
    measured over ``repeats`` full repricings from a warm cache."""
    import numpy as np
    cache = precompute_pricing(net, xs, prof)
    rng = np.random.default_rng(seed)
    pairs = [decode(c) for c in seeded_population(net, prof, size=pop,
                                                  rng=rng)]
    out = {"pop_size": len(pairs)}
    backends = ("numpy", "vmap", "device")
    # warm every path (vmap/device: jit compile; numpy: flow-matrix caches)
    for backend in backends:
        simulate_population(net, xs, prof, pairs, cache=cache,
                            backend=backend)
    for backend in backends:
        t0 = time.perf_counter()
        for _ in range(repeats):
            simulate_population(net, xs, prof, pairs, cache=cache,
                                backend=backend)
        dt = time.perf_counter() - t0
        out[f"{backend}_evals_per_sec"] = repeats * len(pairs) / max(dt, 1e-9)
    out["vmap_speedup"] = (out["vmap_evals_per_sec"]
                           / out["numpy_evals_per_sec"])
    out["device_speedup"] = (out["device_evals_per_sec"]
                             / out["numpy_evals_per_sec"])
    return out


def _generation_throughput(net, xs, prof, *, pop: int, gens: int,
                           seed: int = 0,
                           device_pops: tuple = ()) -> dict:
    """Full-generation throughput of the three search engines on one seeded
    population: numpy engine + numpy pricing, numpy engine + vmap pricing
    (the "vmap-pricing-only" arm — the generation loop is still
    per-offspring host Python), and the device-resident engine.  Each arm
    runs once to warm jit/flow caches, then is timed over a ``gens``-
    generation search; throughput counts generations (and offspring
    pricings) per second.

    ``device_pops`` adds device-engine-only points at larger populations
    (the host engines would dominate the wall clock there) — the scaling
    regime the rank-capped Pareto peeling and the batched archive update
    unlock; recorded as ``device_pop{K}_gens_per_sec``."""
    import numpy as np
    shared = SimEvaluator(net, xs, prof)
    rng = np.random.default_rng(seed)
    seeds = seeded_population(net, prof, size=pop, rng=rng)
    out = {"pop_size": len(seeds), "generations": gens}
    arms = (("numpy", "numpy", "numpy"),
            ("vmap", "numpy", "vmap"),
            ("device", "device", "vmap"))
    for name, engine, backend in arms:
        def run_once(n_gens):
            ev = SimEvaluator(net, xs, prof, cache=shared.cache,
                              population_backend=backend)
            return evolutionary_search(
                net, prof, ev, population_size=len(seeds), generations=n_gens,
                seed=seed, seed_candidates=list(seeds), engine=engine)
        run_once(1)                       # warm jit / flow caches
        t0 = time.perf_counter()
        res = run_once(gens)
        dt = time.perf_counter() - t0
        out[f"{name}_gens_per_sec"] = gens / max(dt, 1e-9)
        out[f"{name}_evals_per_sec"] = res.n_evals / max(dt, 1e-9)
        out[f"{name}_best_time"] = res.report.time_per_step
    out["device_speedup_vs_vmap"] = (out["device_gens_per_sec"]
                                     / out["vmap_gens_per_sec"])
    out["device_speedup_vs_numpy"] = (out["device_gens_per_sec"]
                                      / out["numpy_gens_per_sec"])
    for big in device_pops:
        big_seeds = seeded_population(net, prof, size=big,
                                      rng=np.random.default_rng(seed + 1))
        def run_big(n_gens):
            ev = SimEvaluator(net, xs, prof, cache=shared.cache,
                              population_backend="vmap")
            return evolutionary_search(
                net, prof, ev, population_size=len(big_seeds),
                generations=n_gens, seed=seed,
                seed_candidates=list(big_seeds), engine="device")
        run_big(1)                        # warm jit at this population
        t0 = time.perf_counter()
        res = run_big(gens)
        dt = time.perf_counter() - t0
        out[f"device_pop{big}_size"] = len(big_seeds)
        out[f"device_pop{big}_gens_per_sec"] = gens / max(dt, 1e-9)
        out[f"device_pop{big}_evals_per_sec"] = res.n_evals / max(dt, 1e-9)
    return out


def _sharded_throughput(net, xs, prof, *, pop: int, gens: int,
                        seed: int = 0) -> dict:
    """Equal-total-population head-to-head of the single-device engine vs
    the island-model sharded engine over every visible device.  Both arms
    run the same jitted generation step; the sharded arm splits the
    population into one island per device (``migrate_every=2`` so the run
    exercises the ring collective), warms its compile, then is timed over
    ``gens`` generations."""
    import numpy as np
    import jax
    n_dev = len(jax.devices())
    shared = SimEvaluator(net, xs, prof)
    seeds = seeded_population(net, prof, size=pop,
                              rng=np.random.default_rng(seed))
    seeds = seeds[:len(seeds) - len(seeds) % n_dev]     # equal islands
    out = {"pop_size": len(seeds), "generations": gens, "n_devices": n_dev}
    arms = (("device", "device", {}),
            ("sharded", "sharded", dict(n_islands=n_dev, migrate_every=2)))
    for name, engine, kw in arms:
        def run_once(n_gens, _engine=engine, _kw=kw):
            ev = SimEvaluator(net, xs, prof, cache=shared.cache,
                              population_backend="vmap")
            return evolutionary_search(
                net, prof, ev, population_size=len(seeds),
                generations=n_gens, seed=seed, seed_candidates=list(seeds),
                engine=_engine, **_kw)
        run_once(1)                       # warm jit at this population
        t0 = time.perf_counter()
        res = run_once(gens)
        dt = time.perf_counter() - t0
        out[f"{name}_gens_per_sec"] = gens / max(dt, 1e-9)
        out[f"{name}_evals_per_sec"] = res.n_evals / max(dt, 1e-9)
        out[f"{name}_best_time"] = res.report.time_per_step
    out["sharded_speedup_vs_device"] = (out["sharded_gens_per_sec"]
                                        / out["device_gens_per_sec"])
    return out


def _head_to_head(net, xs, prof, *, population_size: int, generations: int,
                  seed: int = 0, checkpoint_dir: str | None = None,
                  resume: bool = False) -> dict:
    # one pricing cache for every arm; each arm gets its own eval counter
    shared = SimEvaluator(net, xs, prof)

    # paper baseline: minimal partition + ordered mapping
    p0 = minimal_partition(net, prof)
    base = shared(p0, ordered_mapping(p0, prof))

    # greedy §VI-B walk (converges; cannot use more evaluations)
    ev_g = SimEvaluator(net, xs, prof, cache=shared.cache)
    t0 = time.perf_counter()
    greedy = optimize_partitioning(net, prof, ev_g)
    t_greedy = time.perf_counter() - t0
    budget = max(2 * ev_g.n_evals, population_size * (generations + 1))

    # evolutionary pipeline: charged for the greedy evals behind its seeds
    ev_e = SimEvaluator(net, xs, prof, cache=shared.cache)
    t0 = time.perf_counter()
    evo = evolutionary_search(
        net, prof, ev_e, population_size=population_size,
        generations=generations, seed=seed, greedy=greedy,
        max_evaluations=budget - ev_g.n_evals,
        checkpoint_dir=checkpoint_dir, resume=resume)
    t_evo = time.perf_counter() - t0

    # cold start (no greedy seeds), full budget, for reference
    ev_c = SimEvaluator(net, xs, prof, cache=shared.cache)
    t0 = time.perf_counter()
    cold = evolutionary_search(
        net, prof, ev_c, population_size=population_size,
        generations=generations, seed=seed, max_evaluations=budget)
    t_cold = time.perf_counter() - t0

    knee = evo.knee()
    return {
        "budget_evals": budget,
        "front_size": len(evo.front),
        "knee_time": knee[1].time_per_step if knee else None,
        "knee_energy": knee[1].energy_per_step if knee else None,
        "baseline_time": base.time_per_step,
        "greedy_time": greedy.report.time_per_step,
        "greedy_energy": greedy.report.energy_per_step,
        "greedy_evals": ev_g.n_evals,
        "greedy_evals_per_sec": ev_g.n_evals / max(t_greedy, 1e-9),
        "evo_time": evo.report.time_per_step,
        "evo_energy": evo.report.energy_per_step,
        "evo_evals": ev_g.n_evals + evo.n_evals,    # pipeline total
        "evo_evals_per_sec": evo.n_evals / max(t_evo, 1e-9),
        "evo_generations": evo.history[-1].generation,
        "cold_time": cold.report.time_per_step,
        "cold_evals": cold.n_evals,
        "cold_evals_per_sec": cold.n_evals / max(t_cold, 1e-9),
        "speedup_vs_greedy": greedy.report.time_per_step /
        evo.report.time_per_step,
        "speedup_vs_baseline": base.time_per_step / evo.report.time_per_step,
        "energy_vs_greedy": greedy.report.energy_per_step /
        evo.report.energy_per_step,
    }


def run(quick: bool = False, *, checkpoint_dir: str | None = None,
        resume: bool = False) -> dict:
    """``checkpoint_dir`` makes the evolutionary arm of each head-to-head
    crash-safe: per-generation snapshots land under
    ``<checkpoint_dir>/<workload>/`` and ``resume=True`` continues a killed
    run from its newest snapshot (bit-identical to the uninterrupted run —
    see docs/robustness.md)."""
    smoke = os.environ.get("REPRO_BENCH_SMOKE") == "1"
    steps = 2 if smoke else (3 if quick else 6)
    pop = 8 if smoke else (12 if quick else 24)
    gens = 2 if smoke else (5 if quick else 12)
    price_reps = 2 if smoke else (5 if quick else 10)
    # the generation head-to-head: the device engine's advantage is the
    # amortized per-offspring host work, so it is measured at a large
    # population (>= 256 outside the CI smoke path); the device-only
    # pop=1024 point probes the rank-capped-peeling scaling regime
    gen_pop = 64 if smoke else 256
    gen_gens = 2 if smoke else 3
    device_pops = () if smoke else (1024,)

    def ckpt_for(workload: str) -> str | None:
        if checkpoint_dir is None:
            return None
        return os.path.join(checkpoint_dir, workload)

    out = {}
    s5, prof = W.s5_sim(weight_density=0.5, seed=0, weight_format="sparse")
    xs = W.sim_inputs(s5, 0.3, steps, seed=2)
    out["s5"] = _head_to_head(s5, xs, prof, population_size=pop,
                              generations=gens, seed=0,
                              checkpoint_dir=ckpt_for("s5"), resume=resume)
    out["s5"]["pricing"] = _pricing_throughput(s5, xs, prof, pop=64,
                                               repeats=price_reps)
    out["s5"]["generation"] = _generation_throughput(s5, xs, prof,
                                                     pop=gen_pop,
                                                     gens=gen_gens,
                                                     device_pops=device_pops)

    pnet, pprof = W.pilotnet_sim(weight_density=0.6, seed=1)
    pxs = W.sim_inputs(pnet, 0.3, max(steps - 1, 2), seed=3)
    out["pilotnet"] = _head_to_head(pnet, pxs, pprof, population_size=pop,
                                    generations=gens, seed=0,
                                    checkpoint_dir=ckpt_for("pilotnet"),
                                    resume=resume)
    out["pilotnet"]["pricing"] = _pricing_throughput(pnet, pxs, pprof,
                                                     pop=64,
                                                     repeats=price_reps)
    out["pilotnet"]["generation"] = _generation_throughput(pnet, pxs, pprof,
                                                           pop=gen_pop,
                                                           gens=gen_gens)

    # island-model scaling at equal TOTAL population (one island per
    # visible device; meaningful speedups need --devices N on CPU)
    sh_pop = 64 if smoke else (512 if quick else 8192)
    sh_gens = 2 if smoke else (2 if quick else 3)
    out["sharded"] = _sharded_throughput(s5, xs, prof, pop=sh_pop,
                                         gens=sh_gens)

    merge_write_json(BENCH_PATH, out)
    return out


def report(res: dict) -> str:
    lines = ["## search_mapping — greedy §VI-B vs evolutionary "
             "(iso-evaluation budget)"]
    for name in ("s5", "pilotnet"):
        r = res[name]
        lines.append(
            f"  {name:8s} B={r['budget_evals']:<4d} "
            f"greedy={r['greedy_time']:8.1f} ({r['greedy_evals']} evals)  "
            f"evo={r['evo_time']:8.1f} ({r['evo_evals']} evals) "
            f"-> {r['speedup_vs_greedy']:.3f}x vs greedy, "
            f"{r['speedup_vs_baseline']:.2f}x vs baseline")
        lines.append(
            f"  {'':8s} pricing rate: greedy "
            f"{r['greedy_evals_per_sec']:7.1f} evals/s, population "
            f"{r['evo_evals_per_sec']:7.1f} evals/s "
            f"(cold-start evo: {r['cold_time']:.1f})")
        if r.get("knee_time") is not None:
            lines.append(
                f"  {'':8s} front: {r['front_size']} pts, knee "
                f"(time={r['knee_time']:.1f}, "
                f"energy={r['knee_energy']:.1f})")
        pr = r.get("pricing")
        if pr:
            dev = (f", device {pr['device_evals_per_sec']:8.1f} evals/s"
                   if "device_evals_per_sec" in pr else "")
            lines.append(
                f"  {'':8s} population pricing @ pop={pr['pop_size']}: "
                f"numpy {pr['numpy_evals_per_sec']:8.1f} evals/s, "
                f"vmap {pr['vmap_evals_per_sec']:8.1f} evals/s{dev} "
                f"-> {pr['vmap_speedup']:.2f}x")
        ge = r.get("generation")
        if ge:
            lines.append(
                f"  {'':8s} full generations @ pop={ge['pop_size']}: "
                f"numpy {ge['numpy_gens_per_sec']:6.2f} gen/s, "
                f"vmap {ge['vmap_gens_per_sec']:6.2f} gen/s, "
                f"device {ge['device_gens_per_sec']:6.2f} gen/s "
                f"-> device {ge['device_speedup_vs_vmap']:.2f}x vs vmap")
            for key in ge:
                if key.startswith("device_pop") and key.endswith(
                        "_gens_per_sec"):
                    pop_k = key[len("device_pop"):-len("_gens_per_sec")]
                    lines.append(
                        f"  {'':8s} device engine @ pop={pop_k}: "
                        f"{ge[key]:6.2f} gen/s "
                        f"({ge[f'device_pop{pop_k}_evals_per_sec']:8.1f} "
                        f"evals/s)")
    sh = res.get("sharded")
    if sh:
        lines.append(
            f"  sharded islands @ pop={sh['pop_size']} on "
            f"{sh['n_devices']} device(s): "
            f"device {sh['device_gens_per_sec']:6.2f} gen/s, "
            f"sharded {sh['sharded_gens_per_sec']:6.2f} gen/s "
            f"-> {sh['sharded_speedup_vs_device']:.2f}x")
    lines.append(f"  wrote {BENCH_PATH}")
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="greedy vs evolutionary mapping-search head-to-head")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="extra-small sizes for CI (implies --quick)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="snapshot the evolutionary arms per generation "
                         "under <dir>/<workload>/ (crash-safe)")
    ap.add_argument("--resume", action="store_true",
                    help="continue each evolutionary arm from its newest "
                         "snapshot in --checkpoint-dir")
    ap.add_argument("--devices", type=int, default=None,
                    help="force N CPU host devices for the sharded-engine "
                         "section (applied before jax initializes)")
    args = ap.parse_args(argv)
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    os.environ["REPRO_BENCH_SMOKE"] = "1" if args.smoke else "0"
    res = run(quick=args.quick or args.smoke,
              checkpoint_dir=args.checkpoint_dir, resume=args.resume)
    print(report(res))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
