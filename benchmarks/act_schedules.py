"""Fig 5: activation-sparsity schedules and load imbalance (M0).

Claim: with UNIFORM per-layer sparsity, total activation sparsity
correlates ~linearly with time/step; non-uniform schedules (LoHi /
Increasing / Decreasing) at the SAME total sparsity break the correlation.
"""

from __future__ import annotations

import numpy as np

from benchmarks import workloads as W
from repro.neuromorphic.timestep import simulate

TOTALS = [0.8, 0.6, 0.4, 0.2]        # mean activation DENSITY
SCHEDULES = ["uniform", "lohi", "increasing", "decreasing"]
SIZES = (64, 192, 192, 192, 64)


def run(quick: bool = False) -> dict:
    # batched engine: longer windows are ~free -> tighter per-config means
    steps = 3 if quick else 10
    rows = []
    for sched in SCHEDULES:
        for tot in TOTALS:
            dens = W.schedule(sched, len(SIZES) - 1, tot)
            net, prof = W.s5_programmed(
                SIZES, weight_densities=[1.0] * (len(SIZES) - 1),
                act_densities=dens, seed=1)
            xs = W.sim_inputs(net, tot, steps, seed=2)
            r = simulate(net, xs, prof)
            rows.append({"schedule": sched, "total_density": tot,
                         "measured_density": float(np.mean(dens)),
                         "time": r.time_per_step,
                         "max_synops": r.max_synops,
                         "imbalance": r.metrics.synops.imbalance})
    # correlation of time vs density per schedule
    out = {"rows": rows, "corr": {}}
    for sched in SCHEDULES:
        sub = [r for r in rows if r["schedule"] == sched]
        x = np.array([r["total_density"] for r in sub])
        y = np.array([r["time"] for r in sub])
        out["corr"][sched] = float(np.corrcoef(x, y)[0, 1])
    # M0 gap: same total density, different times
    per_tot = {}
    for tot in TOTALS:
        ts = [r["time"] for r in rows if r["total_density"] == tot]
        per_tot[tot] = max(ts) / min(ts)
    out["same_total_time_ratio"] = per_tot
    return out


def report(res: dict) -> str:
    lines = ["## Fig 5 — activation-sparsity schedules (M0)"]
    for sched, c in res["corr"].items():
        lines.append(f"  {sched:11s} corr(time, total density) = {c:+.3f}")
    worst = max(res["same_total_time_ratio"].items(),
                key=lambda kv: kv[1])
    lines.append(f"  same-total-sparsity time ratio up to {worst[1]:.2f}x "
                 f"(density {worst[0]}) -> total sparsity is an unreliable "
                 "proxy under imbalance (paper M0)")
    return "\n".join(lines)
