"""Benchmark driver: one experiment per paper table/figure + the TPU
roofline table + the engine/search microbenchmarks.

``python -m benchmarks.run [--quick] [--smoke] [--only NAME] [--engine E]
[--compute C]``

``--quick`` shrinks every experiment; ``--smoke`` (implies ``--quick``)
shrinks the expensive ones further so the WHOLE suite — including the
mapping-search head-to-head — finishes in a couple of minutes, as a CI
smoke path.  ``--engine`` flips ``repro.neuromorphic.timestep.DEFAULT_ENGINE``
and ``--compute`` flips ``repro.neuromorphic.compute.DEFAULT_COMPUTE`` for
every experiment in the process.  ``--devices N`` forces ``N`` CPU host
devices (via ``repro.launch.mesh.force_host_device_count``, applied
before any benchmark module imports jax) so the sharded-search section
exercises a real multi-device mesh on CPU CI.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="extra-small sizes for CI (implies --quick)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--engine", default=None,
                    choices=("batched", "reference"),
                    help="simulator engine for every experiment "
                         "(default: layer-major batched)")
    ap.add_argument("--compute", default=None,
                    choices=("dense", "event"),
                    help="per-layer synaptic compute backend for every "
                         "experiment (default: dense)")
    ap.add_argument("--arch", default=None,
                    help="registry arch id for the model_zoo experiment "
                         "(default: one smoke arch per family)")
    ap.add_argument("--profile", default=None, metavar="NPZ",
                    help="saved SparsityProfile npz: the sim_speed compute "
                         "sweep adds rows priced under its trained "
                         "densities/masks (falls back to synthetic only)")
    ap.add_argument("--devices", type=int, default=None,
                    help="force N CPU host devices for the sharded-search "
                         "section (must run before jax initializes)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.quick = True
    # authoritative per-invocation: a stale/inherited value must not flip
    # benchmark sizes without the flag
    os.environ["REPRO_BENCH_SMOKE"] = "1" if args.smoke else "0"

    if args.devices is not None:
        # before the repro/benchmark imports below pull in jax
        from repro.launch.mesh import force_host_device_count
        force_host_device_count(args.devices)

    if args.engine:
        from repro.neuromorphic import timestep
        timestep.DEFAULT_ENGINE = args.engine
    if args.compute:
        from repro.neuromorphic import compute
        compute.DEFAULT_COMPUTE = args.compute

    from benchmarks import (act_schedules, compute_floor, iso_accuracy,
                            max_synops, model_zoo, search_mapping,
                            sim_speed, stage1_sparsity,
                            stage2_partitioning, tpu_roofline,
                            traffic_mapping, weight_format,
                            weight_sparsity)

    mods = [
        ("sim_speed", sim_speed),
        ("model_zoo", model_zoo),
        ("fig2_3_weight_sparsity", weight_sparsity),
        ("fig4_weight_format", weight_format),
        ("fig5_act_schedules", act_schedules),
        ("fig6_max_synops", max_synops),
        ("fig7_compute_floor", compute_floor),
        ("fig8_traffic_mapping", traffic_mapping),
        ("fig10_11_stage1", stage1_sparsity),
        ("fig12_stage2", stage2_partitioning),
        ("iso_accuracy", iso_accuracy),
        ("search_mapping", search_mapping),
        ("tpu_roofline", tpu_roofline),
    ]
    results = {}
    stage1_res = None
    for name, mod in mods:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        if mod is stage2_partitioning:
            res = mod.run(args.quick, stage1=stage1_res)
        elif mod is model_zoo:
            res = mod.run(args.quick, arch=args.arch)
        elif mod is sim_speed:
            profile = None
            if args.profile:
                from repro.sparsity import SparsityProfile
                try:
                    profile = SparsityProfile.load(args.profile)
                except (OSError, KeyError, ValueError) as e:
                    print(f"   [--profile {args.profile} unreadable ({e}); "
                          "synthetic compute grid only]")
            res = mod.run(args.quick, profile=profile)
        else:
            res = mod.run(args.quick)
        if mod is stage1_sparsity:
            stage1_res = res
            res = {k: v for k, v in res.items() if not k.startswith("_")}
        dt = time.time() - t0
        print(mod.report(res))
        print(f"   [{name} done in {dt:.1f}s]\n")
        results[name] = res

    if args.only:
        # partial runs refresh their experiments in place instead of
        # truncating everything else previously recorded
        try:
            with open("benchmarks/results.json") as f:
                merged = json.load(f)
        except (OSError, json.JSONDecodeError):
            merged = {}
        merged.update(results)
        results = merged
    with open("benchmarks/results.json", "w") as f:
        json.dump(results, f, indent=1, default=float)
    print("wrote benchmarks/results.json")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
