"""Fig 7: the compute-bound floor moves down with partitioning; energy
rises with utilization.

Claim: at very low max-synops (high sparsity), time hits a floor set by max
per-core activation computes; splitting the compute-bottleneck layer lowers
the floor; every extra core costs power, so energy curves diverge.
"""

from __future__ import annotations

import numpy as np

from benchmarks import workloads as W
from repro.neuromorphic.partition import Partition, minimal_partition
from repro.neuromorphic.noc import strided_mapping
from repro.neuromorphic.timestep import simulate

SIZES = (64, 256, 256, 64)


def run(quick: bool = False) -> dict:
    steps = 3 if quick else 5
    # highly sparse -> synops tiny -> compute-bound
    dens = [0.05] * (len(SIZES) - 1)
    net, prof = W.s5_programmed(
        SIZES, weight_densities=[1.0] * (len(SIZES) - 1),
        act_densities=dens, seed=1)
    xs = W.sim_inputs(net, 0.05, steps, seed=2)
    base = minimal_partition(net, prof)
    rows = []
    for split in (1, 2, 4, 8):
        cores = tuple(min(c * split, 16) for c in base.cores)
        part = Partition(cores)
        r = simulate(net, xs, prof, part, strided_mapping(part, prof))
        rows.append({"split": split, "cores": int(sum(part.cores)),
                     "time": r.time_per_step, "energy": r.energy_per_step,
                     "max_acts": r.max_acts,
                     "bottleneck": r.bottleneck_stage})
    return {"rows": rows,
            "floor_drop": rows[0]["time"] / rows[-1]["time"],
            "energy_rise": rows[-1]["energy"] / rows[0]["energy"]}


def report(res: dict) -> str:
    lines = ["## Fig 7 — compute floor vs partitioning"]
    for r in res["rows"]:
        lines.append(f"  split x{r['split']:<2d} cores={r['cores']:<3d} "
                     f"time={r['time']:9.1f} energy={r['energy']:9.1f} "
                     f"[{r['bottleneck']}]")
    lines.append(f"  floor lowered {res['floor_drop']:.2f}x; energy rose "
                 f"{res['energy_rise']:.2f}x (paper: floor down, power up)")
    return "\n".join(lines)
