"""Simulator-engine microbenchmark: step-major reference vs layer-major
batched execution, plus dense vs event-driven compute backends, on fixed
fc and conv workloads.

Writes ``BENCH_sim.json`` at the repo root with two sections:

* engine rows (``fc`` / ``conv``) — steps/sec per engine + speedup.  The
  fc workload is the acceptance gate for the layer-major engine (>= 10x
  steps/sec); the equivalence suite (``tests/test_sim_equivalence.py``)
  proves the two engines agree exactly, so the speedup is free.
* ``compute`` — dense vs event :class:`~repro.neuromorphic.compute.
  LayerCompute` backends across programmed activation densities
  (0.01–0.5) on characterization-mode fc and conv workloads (§V-A message
  gates; the conv workload programs *channel-structured* activity, the
  granularity event execution exploits on convs).  The headline is the
  event backend's steps/sec advantage *growing as density falls* — the
  simulator's own execution cost now scales with events, like the
  hardware it models — while ``tests/test_compute_backends.py`` proves
  both backends price identically.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import workloads as W
from repro.neuromorphic import (fc_network, loihi2_like, make_inputs,
                                programmed_fc_network)
from repro.neuromorphic.timestep import simulate

BENCH_PATH = "BENCH_sim.json"

#: programmed activation densities of the compute-backend sweep
COMPUTE_DENSITIES = (0.01, 0.05, 0.1, 0.2, 0.5)


def _time_engine(net, xs, prof, engine: str, repeats: int = 3) -> float:
    """Best-of-N wall-clock for one simulate() call, in seconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        simulate(net, xs, prof, engine=engine)
        best = min(best, time.perf_counter() - t0)
    return best


def _bench(name: str, net, xs, prof, repeats: int) -> dict:
    simulate(net, xs, prof, engine="batched")      # warm jit/caches
    T = xs.shape[0]
    t_ref = _time_engine(net, xs, prof, "reference", repeats)
    t_bat = _time_engine(net, xs, prof, "batched", repeats)
    row = {
        "workload": name,
        "steps": T,
        "ref_steps_per_sec": T / t_ref,
        "batched_steps_per_sec": T / t_bat,
        "speedup": t_ref / t_bat,
    }
    return row


def _time_run_batch_pair(net, xs, repeats: int) -> tuple[float, float]:
    """Best-of-N wall-clock of the functional layer-major run — the seam
    the compute backends plug into — for the dense and event backends,
    interleaved so host-load drift biases neither arm."""
    best = {"dense": float("inf"), "event": float("inf")}
    for backend in best:
        net.run_batch(xs, compute=backend)       # warm jit / weight caches
    for _ in range(repeats):
        for backend in best:
            t0 = time.perf_counter()
            net.run_batch(xs, compute=backend)
            best[backend] = min(best[backend], time.perf_counter() - t0)
    return best["dense"], best["event"]


def _compute_fc_workload(density: float, steps: int, quick: bool):
    """Characterization-mode fc stack: per-layer message gates program the
    activation density exactly (paper §V-A); the input layer is kept small
    so the gated layers carry the compute."""
    sizes = ([128, 384, 384, 256] if quick
             else [256, 1024, 1024, 1024, 512])
    net = programmed_fc_network(sizes, weight_densities=[1.0] * (len(sizes) - 1),
                                act_densities=[density] * (len(sizes) - 1),
                                seed=0)
    xs = make_inputs(sizes[0], density, steps, seed=1)
    return net, xs


def _compute_conv_workload(density: float, steps: int, quick: bool):
    """Channel-structured characterization conv: whole feature maps are
    gated on/off (the structure event-driven conv execution exploits —
    quiet channels fetch no weight taps), and the input programs the same
    per-channel activity."""
    hw = (16, 16) if quick else (32, 32)
    cin = 4 if quick else 8
    channels = (16, 32) if quick else (32, 64, 64)
    net = W.conv_net(in_hw=hw, cin=cin, channels=channels, fc_out=16,
                     force_active=True, seed=0)
    rng = np.random.default_rng(7)
    for l in net.layers:
        if l.kind != "conv":
            continue
        cout = l.weights.shape[3]
        chm = np.zeros(cout, np.float32)
        chm[rng.choice(cout, max(1, round(density * cout)),
                       replace=False)] = 1.0
        l.msg_gate = np.repeat(chm, l.out_hw[0] * l.out_hw[1])
    xs = make_inputs(net.in_size, 1.0, steps, seed=1)
    in_chm = np.zeros(cin, np.float32)
    in_chm[rng.choice(cin, max(1, round(density * cin)), replace=False)] = 1.0
    xs = (xs.reshape(steps, cin, -1) * in_chm[None, :, None]).reshape(
        steps, -1)
    return net, xs


def _bench_compute(quick: bool, repeats: int) -> dict:
    """Dense vs event backend steps/sec across programmed densities."""
    out = {}
    for name, make, steps in (
            ("fc", _compute_fc_workload, 32 if quick else 128),
            ("conv", _compute_conv_workload, 8 if quick else 32)):
        rows = []
        for d in COMPUTE_DENSITIES:
            net, xs = make(d, steps, quick)
            t_dense, t_event = _time_run_batch_pair(net, xs, repeats)
            rows.append({
                "density": d,
                "steps": steps,
                "dense_steps_per_sec": steps / t_dense,
                "event_steps_per_sec": steps / t_event,
                "event_speedup": t_dense / t_event,
            })
        out[name] = rows
    return out


def run(quick: bool = False) -> dict:
    steps = 64 if quick else 256
    repeats = 2 if quick else 3

    fc = fc_network([128, 256, 256, 256, 128, 64], weight_density=0.5,
                    seed=0)
    fc_xs = make_inputs(128, 0.5, steps, seed=1)

    conv, conv_prof = W.akidanet_sim(weight_density=0.6, seed=0)
    conv_xs = W.sim_inputs(conv, 0.5, max(steps // 4, 16), seed=1)

    out = {
        "fc": _bench("fc", fc, fc_xs, loihi2_like(), repeats),
        "conv": _bench("conv", conv, conv_xs, conv_prof, repeats),
        # full runs average harder (noisy shared hosts); quick/smoke keeps
        # its reduced repeat count
        "compute": _bench_compute(quick, repeats if quick
                                  else max(repeats, 5)),
    }
    from benchmarks._bench_io import merge_write_json
    merge_write_json(BENCH_PATH, out)
    return out


def report(res: dict) -> str:
    lines = ["## sim_speed — step-major vs layer-major engine"]
    for name in ("fc", "conv"):
        r = res[name]
        lines.append(
            f"  {name:5s} T={r['steps']:<4d} "
            f"ref={r['ref_steps_per_sec']:8.1f} steps/s  "
            f"batched={r['batched_steps_per_sec']:10.1f} steps/s  "
            f"-> {r['speedup']:.1f}x")
    comp = res.get("compute")
    if comp:
        lines.append("  compute backends — dense vs event "
                     "(programmed act density)")
        for name in ("fc", "conv"):
            for r in comp[name]:
                lines.append(
                    f"    {name:5s} d={r['density']:<5g} "
                    f"dense={r['dense_steps_per_sec']:9.1f} steps/s  "
                    f"event={r['event_steps_per_sec']:9.1f} steps/s  "
                    f"-> {r['event_speedup']:.2f}x")
    lines.append(f"  wrote {BENCH_PATH}")
    return "\n".join(lines)
