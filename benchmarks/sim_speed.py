"""Simulator-engine microbenchmark: step-major reference vs layer-major
batched execution, plus dense vs event-driven compute backends, on fixed
fc and conv workloads.

Writes ``BENCH_sim.json`` at the repo root with two sections:

* engine rows (``fc`` / ``conv``) — steps/sec per engine + speedup.  The
  fc workload is the acceptance gate for the layer-major engine (>= 10x
  steps/sec); the equivalence suite (``tests/test_sim_equivalence.py``)
  proves the two engines agree exactly, so the speedup is free.
* ``compute`` — dense vs event :class:`~repro.neuromorphic.compute.
  LayerCompute` backends over a 2-D ``(act_density, weight_density)`` grid
  on characterization-mode fc and conv workloads (§V-A message gates; the
  conv workload programs *channel-structured* activity, the granularity
  event execution exploits on convs).  Weight sparsity is *structured* —
  whole (128, 128) weight tiles dead on fc, whole input channels dead on
  conv — because that is what the block-CSR skip machinery converts into
  skipped fetches (the paper's CNN weight-format finding; unstructured
  masks leave tile occupancy near 1 and win nothing).  The headline is the
  event backend's advantage growing along BOTH axes — work now scales with
  ``act_density x weight_density`` — while ``tests/test_weight_sparse.py``
  proves both backends price identically.  ``--profile <npz>`` adds rows
  priced under a trained :class:`~repro.sparsity.SparsityProfile` (real
  unstructured masks, honestly recorded next to the synthetic grid), and
  ``sd_window`` rows compare windowed vs dense-cumsum delta reconstruction
  on bursty sigma-delta workloads.

Rerun just the compute sweep (the sections produced are merged into
``BENCH_sim.json`` atomically, leaving the rest in place)::

    PYTHONPATH=src python -m benchmarks.sim_speed --compute [--quick]
    [--profile experiments/profile.npz]
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import workloads as W
from repro.neuromorphic import (fc_network, loihi2_like, make_inputs,
                                programmed_fc_network)
from repro.neuromorphic.compute import EventCompute
from repro.neuromorphic.network import _exact_density_mask
from repro.neuromorphic.timestep import simulate

BENCH_PATH = "BENCH_sim.json"

#: programmed activation densities of the compute-backend sweep
COMPUTE_DENSITIES = (0.01, 0.05, 0.1, 0.2, 0.5)
#: structured weight densities of the 2-D sweep (1.0 = the old 1-D sweep)
COMPUTE_WEIGHT_DENSITIES = (1.0, 0.5, 0.1)
#: fraction of 32-step windows carrying events in the sd_window sweep
SD_DUTY_FRACTIONS = (0.0625, 0.25, 1.0)


def _time_engine(net, xs, prof, engine: str, repeats: int = 3) -> float:
    """Best-of-N wall-clock for one simulate() call, in seconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        simulate(net, xs, prof, engine=engine)
        best = min(best, time.perf_counter() - t0)
    return best


def _bench(name: str, net, xs, prof, repeats: int) -> dict:
    simulate(net, xs, prof, engine="batched")      # warm jit/caches
    T = xs.shape[0]
    t_ref = _time_engine(net, xs, prof, "reference", repeats)
    t_bat = _time_engine(net, xs, prof, "batched", repeats)
    row = {
        "workload": name,
        "steps": T,
        "ref_steps_per_sec": T / t_ref,
        "batched_steps_per_sec": T / t_bat,
        "speedup": t_ref / t_bat,
    }
    return row


def _time_run_batch_pair(net, xs, repeats: int) -> tuple[float, float]:
    """Best-of-N wall-clock of the functional layer-major run — the seam
    the compute backends plug into — for the dense and event backends,
    interleaved so host-load drift biases neither arm."""
    best = {"dense": float("inf"), "event": float("inf")}
    for backend in best:
        net.run_batch(xs, compute=backend)       # warm jit / weight caches
    for _ in range(repeats):
        for backend in best:
            t0 = time.perf_counter()
            net.run_batch(xs, compute=backend)
            best[backend] = min(best[backend], time.perf_counter() - t0)
    return best["dense"], best["event"]


def _tile_mask_fc_weights(net, weight_density: float, *, bk: int = 128,
                          bn: int = 128, seed: int = 3) -> None:
    """Kill whole (bk, bn) weight tiles to an exact tile density on every
    fc layer: the structured weight sparsity the block-CSR occupancy map
    converts into skipped DMAs (unstructured masks leave nearly every tile
    occupied — the paper's CNN structure finding)."""
    if weight_density >= 1.0:
        return
    rng = np.random.default_rng(seed)
    for l in net.layers:
        if l.kind != "fc":
            continue
        K, N = l.weights.shape
        kb, nb = -(-K // bk), -(-N // bn)
        tm = _exact_density_mask((kb, nb), weight_density, rng)
        l.weights = l.weights * np.repeat(np.repeat(tm, bk, axis=0), bn,
                                          axis=1)[:K, :N]


def _channel_mask_conv_weights(net, weight_density: float, *,
                               seed: int = 5) -> None:
    """Kill all taps of whole input channels on every conv layer: the
    channel-structured weight sparsity whose dead patch-weight rows the
    gather path's CSR row skipping never fetches."""
    if weight_density >= 1.0:
        return
    rng = np.random.default_rng(seed)
    for l in net.layers:
        if l.kind != "conv":
            continue
        cin = l.weights.shape[2]
        chm = np.zeros(cin, np.float32)
        chm[rng.choice(cin, max(1, round(weight_density * cin)),
                       replace=False)] = 1.0
        l.weights = l.weights * chm[None, None, :, None]


def _compute_fc_workload(density: float, steps: int, quick: bool,
                         weight_density: float = 1.0):
    """Characterization-mode fc stack: per-layer message gates program the
    activation density exactly (paper §V-A); the input layer is kept small
    so the gated layers carry the compute.  ``weight_density`` kills whole
    128x128 weight tiles (structured)."""
    sizes = ([128, 384, 384, 256] if quick
             else [256, 1024, 1024, 1024, 512])
    net = programmed_fc_network(sizes, weight_densities=[1.0] * (len(sizes) - 1),
                                act_densities=[density] * (len(sizes) - 1),
                                seed=0)
    _tile_mask_fc_weights(net, weight_density)
    xs = make_inputs(sizes[0], density, steps, seed=1)
    return net, xs


def _compute_conv_workload(density: float, steps: int, quick: bool,
                           weight_density: float = 1.0):
    """Channel-structured characterization conv: whole feature maps are
    gated on/off (the structure event-driven conv execution exploits —
    quiet channels fetch no weight taps), and the input programs the same
    per-channel activity.  ``weight_density`` kills whole input channels'
    taps (structured weight sparsity)."""
    hw = (16, 16) if quick else (32, 32)
    cin = 4 if quick else 8
    channels = (16, 32) if quick else (32, 64, 64)
    net = W.conv_net(in_hw=hw, cin=cin, channels=channels, fc_out=16,
                     force_active=True, seed=0)
    rng = np.random.default_rng(7)
    for l in net.layers:
        if l.kind != "conv":
            continue
        cout = l.weights.shape[3]
        chm = np.zeros(cout, np.float32)
        chm[rng.choice(cout, max(1, round(density * cout)),
                       replace=False)] = 1.0
        l.msg_gate = np.repeat(chm, l.out_hw[0] * l.out_hw[1])
    _channel_mask_conv_weights(net, weight_density)
    xs = make_inputs(net.in_size, 1.0, steps, seed=1)
    in_chm = np.zeros(cin, np.float32)
    in_chm[rng.choice(cin, max(1, round(density * cin)), replace=False)] = 1.0
    xs = (xs.reshape(steps, cin, -1) * in_chm[None, :, None]).reshape(
        steps, -1)
    return net, xs


def _bench_compute(quick: bool, repeats: int, profile=None) -> dict:
    """Dense vs event backend steps/sec over the 2-D
    (act_density, weight_density) grid, plus trained-profile rows."""
    out = {}
    for name, make, steps in (
            ("fc", _compute_fc_workload, 32 if quick else 128),
            ("conv", _compute_conv_workload, 8 if quick else 32)):
        rows = []
        for d in COMPUTE_DENSITIES:
            for wd in COMPUTE_WEIGHT_DENSITIES:
                net, xs = make(d, steps, quick, wd)
                t_dense, t_event = _time_run_batch_pair(net, xs, repeats)
                rows.append({
                    "act_density": d,
                    "weight_density": wd,
                    "weight_structure": "tile" if name == "fc" else "channel",
                    "steps": steps,
                    "dense_steps_per_sec": steps / t_dense,
                    "event_steps_per_sec": steps / t_event,
                    "event_speedup": t_dense / t_event,
                })
        out[name] = rows
    if profile is not None:
        out["trained_profile"] = _bench_profile_rows(profile, quick, repeats)
    out["sd_window"] = _bench_sd_window(quick, repeats)
    return out


def _bench_profile_rows(profile, quick: bool, repeats: int) -> list[dict]:
    """Rows priced under a trained SparsityProfile artifact: the exact
    masks a sparse-training run produced (typically *unstructured* —
    recorded honestly next to the synthetic structured grid, where the
    tile-skip machinery has little to grab onto)."""
    sizes = [int(profile.weight_masks[0].shape[0])] + [
        int(m.shape[1]) for m in profile.weight_masks] \
        if profile.weight_masks else [128, 384, 256]
    steps = 32 if quick else 128
    net = programmed_fc_network(
        sizes, weight_densities=[1.0] * (len(sizes) - 1),
        act_densities=[float(d) for d in
                       profile.densities_for(len(sizes) - 1)], seed=0)
    net = profile.apply(net, seed=17)
    xs = make_inputs(sizes[0], float(profile.input_density), steps, seed=1)
    t_dense, t_event = _time_run_batch_pair(net, xs, repeats)
    return [{
        "source": "trained_profile",
        "act_density": float(np.mean(profile.act_density)),
        "weight_density": float(np.mean(profile.weight_density)),
        "weight_structure": "unstructured",
        "steps": steps,
        "dense_steps_per_sec": steps / t_dense,
        "event_steps_per_sec": steps / t_event,
        "event_speedup": t_dense / t_event,
    }]


def _bench_sd_window(quick: bool, repeats: int) -> list[dict]:
    """Temporal-tile sigma-delta: windowed delta reconstruction vs the
    dense time-cumsum event path on bursty workloads — inputs carry events
    only in the first ``duty`` fraction of each 128-step burst period, and
    the 32-step reconstruction window divides the period, so low-duty
    workloads have whole windows with zero deltas: exactly what the
    windowed path compacts away (window == period would put the burst in
    every window and skip nothing)."""
    sizes = [128, 384, 384, 256] if quick else [256, 1024, 1024, 512]
    steps = 256 if quick else 512
    period, win = 128, 32
    rows = []
    for duty in SD_DUTY_FRACTIONS:
        net = fc_network(sizes, weight_density=1.0, seed=0,
                         neuron_model="sd_relu")
        for l in net.layers:
            l.threshold = 0.05
            l.sends_deltas = True
        xs = make_inputs(sizes[0], 0.5, steps, seed=1)
        keep = max(1, round(duty * period))
        xs[np.arange(steps) % period >= keep] = 0.0   # bursty: quiet windows
        window = EventCompute(mode="gather", delta_mode="window",
                              delta_window=win)
        cumsum = EventCompute(mode="gather", delta_mode="cumsum")
        best = {"window": float("inf"), "cumsum": float("inf")}
        for cc in (window, cumsum):
            net.run_batch(xs, compute=cc)              # warm caches
        for _ in range(repeats):
            for key, cc in (("window", window), ("cumsum", cumsum)):
                t0 = time.perf_counter()
                net.run_batch(xs, compute=cc)
                best[key] = min(best[key], time.perf_counter() - t0)
        rows.append({
            "duty": duty,
            "steps": steps,
            "window": win,
            "period": period,
            "cumsum_steps_per_sec": steps / best["cumsum"],
            "window_steps_per_sec": steps / best["window"],
            "window_speedup": best["cumsum"] / best["window"],
        })
    return rows


def run(quick: bool = False, *, profile=None, only: str | None = None) -> dict:
    """``only=None`` runs everything; ``only="compute"`` reruns just the
    compute sweep (its sections merge into ``BENCH_sim.json`` atomically,
    leaving the engine rows in place — and vice versa)."""
    steps = 64 if quick else 256
    repeats = 2 if quick else 3

    out = {}
    if only in (None, "engine"):
        fc = fc_network([128, 256, 256, 256, 128, 64], weight_density=0.5,
                        seed=0)
        fc_xs = make_inputs(128, 0.5, steps, seed=1)

        conv, conv_prof = W.akidanet_sim(weight_density=0.6, seed=0)
        conv_xs = W.sim_inputs(conv, 0.5, max(steps // 4, 16), seed=1)
        out["fc"] = _bench("fc", fc, fc_xs, loihi2_like(), repeats)
        out["conv"] = _bench("conv", conv, conv_xs, conv_prof, repeats)
    if only in (None, "compute"):
        # full runs average harder (noisy shared hosts); quick/smoke keeps
        # its reduced repeat count
        out["compute"] = _bench_compute(quick, repeats if quick
                                        else max(repeats, 5),
                                        profile=profile)
    from benchmarks._bench_io import merge_write_json
    merge_write_json(BENCH_PATH, out)
    return out


def report(res: dict) -> str:
    lines = ["## sim_speed — step-major vs layer-major engine"]
    for name in ("fc", "conv"):
        r = res.get(name)
        if r is None:
            continue
        lines.append(
            f"  {name:5s} T={r['steps']:<4d} "
            f"ref={r['ref_steps_per_sec']:8.1f} steps/s  "
            f"batched={r['batched_steps_per_sec']:10.1f} steps/s  "
            f"-> {r['speedup']:.1f}x")
    comp = res.get("compute")
    if comp:
        lines.append("  compute backends — dense vs event "
                     "(act density x structured weight density)")
        for name in ("fc", "conv", "trained_profile"):
            for r in comp.get(name, ()):
                lines.append(
                    f"    {name:15s} d={r['act_density']:<5g} "
                    f"wd={r['weight_density']:<5g} "
                    f"dense={r['dense_steps_per_sec']:9.1f} steps/s  "
                    f"event={r['event_steps_per_sec']:9.1f} steps/s  "
                    f"-> {r['event_speedup']:.2f}x")
        for r in comp.get("sd_window", ()):
            lines.append(
                f"    sd_window duty={r['duty']:<7g} "
                f"cumsum={r['cumsum_steps_per_sec']:9.1f} steps/s  "
                f"window={r['window_steps_per_sec']:9.1f} steps/s  "
                f"-> {r['window_speedup']:.2f}x")
    lines.append(f"  wrote {BENCH_PATH}")
    return "\n".join(lines)


def main(argv=None) -> int:
    import argparse

    from repro.sparsity import SparsityProfile

    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--compute", action="store_true",
                    help="rerun only the compute-backend sweep (merged "
                         "into BENCH_sim.json; engine rows untouched)")
    ap.add_argument("--engine", action="store_true",
                    help="rerun only the engine rows")
    ap.add_argument("--profile", default=None, metavar="NPZ",
                    help="price extra compute rows under a saved "
                         "SparsityProfile (falls back to the synthetic "
                         "grid alone if the file is unreadable)")
    args = ap.parse_args(argv)
    profile = None
    if args.profile:
        try:
            profile = SparsityProfile.load(args.profile)
        except (OSError, KeyError, ValueError) as e:
            print(f"  [--profile {args.profile} unreadable ({e}); "
                  "synthetic grid only]")
    only = None
    if args.compute and not args.engine:
        only = "compute"
    elif args.engine and not args.compute:
        only = "engine"
    print(report(run(args.quick, profile=profile, only=only)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
