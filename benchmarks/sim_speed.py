"""Simulator-engine microbenchmark: step-major reference vs layer-major
batched execution on fixed fc and conv workloads.

Writes ``BENCH_sim.json`` (steps/sec per engine + speedup) at the repo
root.  The fc workload is the acceptance gate for the layer-major engine
(>= 10x steps/sec); the equivalence suite
(``tests/test_sim_equivalence.py``) proves the two engines agree exactly,
so the speedup is free.
"""

from __future__ import annotations

import json
import time

from benchmarks import workloads as W
from repro.neuromorphic import fc_network, loihi2_like, make_inputs
from repro.neuromorphic.timestep import simulate

BENCH_PATH = "BENCH_sim.json"


def _time_engine(net, xs, prof, engine: str, repeats: int = 3) -> float:
    """Best-of-N wall-clock for one simulate() call, in seconds."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        simulate(net, xs, prof, engine=engine)
        best = min(best, time.perf_counter() - t0)
    return best


def _bench(name: str, net, xs, prof, repeats: int) -> dict:
    simulate(net, xs, prof, engine="batched")      # warm jit/caches
    T = xs.shape[0]
    t_ref = _time_engine(net, xs, prof, "reference", repeats)
    t_bat = _time_engine(net, xs, prof, "batched", repeats)
    row = {
        "workload": name,
        "steps": T,
        "ref_steps_per_sec": T / t_ref,
        "batched_steps_per_sec": T / t_bat,
        "speedup": t_ref / t_bat,
    }
    return row


def run(quick: bool = False) -> dict:
    steps = 64 if quick else 256
    repeats = 2 if quick else 3

    fc = fc_network([128, 256, 256, 256, 128, 64], weight_density=0.5,
                    seed=0)
    fc_xs = make_inputs(128, 0.5, steps, seed=1)

    conv, conv_prof = W.akidanet_sim(weight_density=0.6, seed=0)
    conv_xs = W.sim_inputs(conv, 0.5, max(steps // 4, 16), seed=1)

    out = {
        "fc": _bench("fc", fc, fc_xs, loihi2_like(), repeats),
        "conv": _bench("conv", conv, conv_xs, conv_prof, repeats),
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(out, f, indent=1)
    return out


def report(res: dict) -> str:
    lines = ["## sim_speed — step-major vs layer-major engine"]
    for name in ("fc", "conv"):
        r = res[name]
        lines.append(
            f"  {name:5s} T={r['steps']:<4d} "
            f"ref={r['ref_steps_per_sec']:8.1f} steps/s  "
            f"batched={r['batched_steps_per_sec']:10.1f} steps/s  "
            f"-> {r['speedup']:.1f}x")
    lines.append(f"  wrote {BENCH_PATH}")
    return "\n".join(lines)
