"""Fig 12 + §VII-C: stage-2 floorline-informed partitioning/mapping on the
stage-1 winners, and the combined two-stage totals."""

from __future__ import annotations

import numpy as np

from benchmarks import stage1_sparsity as s1
from benchmarks import workloads as W
from repro.core.partitioner import SimEvaluator, optimize_partitioning
from repro.neuromorphic.noc import ordered_mapping
from repro.neuromorphic.partition import minimal_partition
from repro.neuromorphic.platform import loihi2_like
from repro.neuromorphic.timestep import simulate
from repro.train.data import SyntheticDenoise


def _optimize(net, prof, xs):
    # SimEvaluator builds the batched engine's pricing cache once and
    # re-prices every candidate counter-free (reference engine: no cache)
    return optimize_partitioning(net, prof, SimEvaluator(net, xs, prof))


def run(quick: bool = False, stage1=None) -> dict:
    stage1 = stage1 or s1.run(quick)
    prof = loihi2_like()
    data = SyntheticDenoise(n_features=64, seq_len=24, global_batch=16,
                            seed=3)
    seq = np.asarray(data.batch(1234)["noisy"][0], np.float32)
    out = {}

    # ---- S5: sparse star network, packed into fewer cores ----------------
    s5_rows = stage1["_s5_full"]
    base_row = next(r for r in s5_rows if r["baseline"])
    # star: sparsest network within MSE budget
    ok = [r for r in s5_rows if r["mse"] <= base_row["mse"] * 1.3 + 1e-6
          and not r["baseline"]]
    star = max(ok, key=lambda r: r["sparsity"]) if ok else s5_rows[1]
    net_base = s1._deploy_fc([np.asarray(w) for w in base_row["tuned"]],
                             neuron_model="ssm")
    net_star = s1._deploy_fc([np.asarray(w) for w in star["tuned"]],
                             neuron_model="ssm")
    # paper baseline: dense minimal partition + ordered mapping
    p0 = minimal_partition(net_base, prof)
    r_base = simulate(net_base, seq, prof, p0, ordered_mapping(p0, prof))
    opt = _optimize(net_star, prof, seq)
    out["s5"] = {
        "baseline_time": r_base.time_per_step,
        "baseline_energy": r_base.energy_per_step,
        "stage1_time": next(
            h.time for h in [opt.history[0]]),
        "final_time": opt.report.time_per_step,
        "final_energy": opt.report.energy_per_step,
        "iterations": [
            {"it": h.iteration, "assumption": h.assumption.value,
             "move": h.move, "time": h.time, "energy": h.energy,
             "max_synops": h.max_synops, "accepted": h.accepted}
            for h in opt.history],
        "stage2_speedup": opt.history[0].time / opt.report.time_per_step,
        "combined_speedup": r_base.time_per_step / opt.report.time_per_step,
        "combined_energy": r_base.energy_per_step /
        opt.report.energy_per_step,
    }

    # ---- PilotNet-like: per-layer-threshold star, then partition ---------
    pb = stage1["pilotnet"]
    # rebuild the per-layer-targets network for partition optimization
    rows = s1.pilotnet_thresholds(quick)
    net_p, prof_p = W.pilotnet_sim(seed=1)      # structural stand-in
    xs = W.sim_inputs(net_p, 0.3, 3 if quick else 5, seed=2)
    p0 = minimal_partition(net_p, prof_p)
    r_base = simulate(net_p, xs, prof_p, p0, ordered_mapping(p0, prof_p))
    opt = _optimize(net_p, prof_p, xs)
    out["pilotnet"] = {
        "baseline_time": r_base.time_per_step,
        "final_time": opt.report.time_per_step,
        "stage2_speedup": opt.history[0].time / opt.report.time_per_step,
        "combined_speedup": (pb[0]["time"] / pb[1]["time"]) *
        (opt.history[0].time / opt.report.time_per_step),
        "iterations": [
            {"it": h.iteration, "assumption": h.assumption.value,
             "move": h.move, "time": h.time, "accepted": h.accepted}
            for h in opt.history],
    }
    return out


def report(res: dict) -> str:
    lines = ["## Fig 12 / §VII-C — stage-2 partitioning + combined"]
    s5 = res["s5"]
    lines.append(f"  s5       stage2 {s5['stage2_speedup']:.2f}x "
                 f"(paper 1.83x); combined vs manual baseline "
                 f"{s5['combined_speedup']:.2f}x time, "
                 f"{s5['combined_energy']:.2f}x energy "
                 "(paper 1.99x / 3.38x)")
    pn = res["pilotnet"]
    lines.append(f"  pilotnet stage2 {pn['stage2_speedup']:.2f}x "
                 f"(paper 1.73x); combined {pn['combined_speedup']:.2f}x "
                 "(paper 3.86x)")
    n_acc = sum(1 for h in s5["iterations"] if h["accepted"])
    lines.append(f"  s5 optimizer: {len(s5['iterations'])} iterations, "
                 f"{n_acc} accepted (traces the memory slope)")
    return "\n".join(lines)
