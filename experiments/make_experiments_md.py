"""Generate EXPERIMENTS.md from committed artifacts:
experiments/dryrun/*.json (sweep), experiments/perf/*.json (hillclimb),
benchmarks/results.json (paper figures).

  PYTHONPATH=src python experiments/make_experiments_md.py
"""

import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "experiments", "dryrun")
PERF = os.path.join(ROOT, "experiments", "perf")


def _load(d, suffix):
    out = []
    if os.path.isdir(d):
        for f in sorted(os.listdir(d)):
            if f.endswith(suffix):
                out.append(json.load(open(os.path.join(d, f))))
    return out


def _gb(x):
    return f"{x / 2**30:.2f}"


def dryrun_section(recs):
    lines = [
        "## §Dry-run — 32 assigned cells x {single-pod 16x16=256, "
        "multi-pod 2x16x16=512}, all lower+compile",
        "",
        "`.lower().compile()` succeeds for every (arch x shape x mesh); "
        "memory_analysis proves per-chip fit (v5e = 16 GiB HBM). "
        "Skips per DESIGN.md: long_500k only for sub-quadratic archs "
        "(mamba2, recurrentgemma).",
        "",
        "| arch | shape | mesh | chips | opt | mb | args GiB/chip | "
        "temp GiB/chip | state GiB/chip | collective bytes/chip/step | "
        "compile s |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        m = r.get("memory_analysis", {})
        hc = r.get("hlo_cost", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['n_chips']} "
            f"| {r.get('optimizer', '-')} | {r.get('microbatches', '-')} "
            f"| {_gb(m.get('argument_bytes', 0))} "
            f"| {_gb(m.get('temp_bytes', 0))} "
            f"| {_gb(r.get('state_bytes_per_device', 0))} "
            f"| {hc.get('collective_bytes', 0):.3e} "
            f"| {r.get('compile_s', 0):.0f} |")
    lines += [
        "",
        "Notes:",
        "* `cost_analysis()` counts scan bodies once (verified: "
        "tests/test_tpu_floorline.py); all FLOP/byte numbers here use the "
        "trip-count-aware analyzer `repro.core.hlo_cost` (DESIGN.md §8).",
        "* kimi-k2 (1.03T params) trains with Adafactor (factored states) "
        "— Adam would need ~16 TB of optimizer state; experts shard over "
        "`data` (EP, intra-pod ICI) x expert-FF over `model`; pods are "
        "pure DP (only gradient reduce-scatters cross the DCI).",
        "* Fit caveat (kimi-k2 cells): persistent per-chip STATE fits "
        "(train 11.2 GiB, decode 12.8 GiB < 16 GiB), but the CPU-compiled "
        "temp accounting reports 25-59 GiB of transients — XLA:CPU performs "
        "no TPU-grade buffer reuse/rematerialization in its "
        "memory_analysis, and the Adafactor update materializes f32 views "
        "of the bf16 expert shards. The TPU-side fixes are standard "
        "(chunked optimizer update over the expert axis + TPU buffer "
        "assignment); every other arch's cells fit outright "
        "(temps <= 3.6 GiB).",
    ]
    return "\n".join(lines)


def roofline_section(recs):
    lines = [
        "## §Roofline — single-pod (16x16), per cell",
        "",
        "Terms (seconds/step/chip): compute = FLOPs/197e12; memory = HBM "
        "bytes/819e9 (flash-adjusted: attention scores are VMEM-resident "
        "under kernels/flash_attn — raw value retained in artifacts); "
        "collective = collective operand bytes/50e9. "
        "MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference). "
        "useful = MODEL_FLOPS / (HLO_FLOPs x chips). roofline% = useful "
        "compute time / bound.",
        "",
        "| arch | shape | t_comp | t_mem | t_coll | bound s | dominant | "
        "useful | roofl% | what would move the dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    from repro.core.analytical import Bottleneck  # noqa
    for r in recs:
        t = r["roofline"]
        hints = {
            "memory": "fewer weight re-reads (larger microbatch), bf16 "
            "stream, less remat",
            "compute": "cut remat recompute / redundant projections",
            "traffic": "SP-sliced dispatch, reduce-scatter not all-reduce, "
            "overlap",
        }
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['t_compute_s']:.4f} "
            f"| {t['t_memory_s']:.4f} | {t['t_collective_s']:.4f} "
            f"| {t['bound_s']:.4f} | {t['dominant']} "
            f"| {t['useful_flops_ratio']:.3f} "
            f"| {t['roofline_fraction'] * 100:.1f}% "
            f"| {hints[t['dominant']]} |")
    doms = {}
    for r in recs:
        d = r["roofline"]["dominant"]
        doms[d] = doms.get(d, 0) + 1
    lines += ["", f"Dominant-term counts: {doms}."]
    return "\n".join(lines)


def perf_section():
    recs = _load(PERF, ".json")
    by = {}
    for r in recs:
        key = r["arch"]
        tag = r["mesh"].split("__")[-1] if "__" in r["mesh"] else "base"
        by.setdefault(key, {})[tag] = r
    lines = ["## §Perf — hillclimb on the three selected cells", ""]
    lines.append(
        "Cells: kimi-k2 train_4k (most representative of the paper's "
        "technique: expert load ≡ neurocore load, M0), olmoe train_4k "
        "(most collective-bound baseline), gemma2 train_4k (worst "
        "useful-ratio dense cell; context-parallel attention). "
        "Method: §VI-B backtracking — hypothesis -> change -> re-lower -> "
        "measure -> accept/backtrack (see table per cell).")
    hypo = {
        "spdisp": "MoE a2a payload is replicated over the 16 TP shards; "
        "slicing d over `model` (sp_dispatch) cuts dispatch wire bytes "
        "~16x and turns the combine all-reduce into a reduce-scatter "
        "(predicted: collective term down several x)",
        "mb4": "each microbatch re-reads all weights in fwd+bwd(+remat); "
        "M: 16->4 cuts weight HBM traffic ~4x at 4x activation footprint "
        "(predicted: memory term down up to ~3x if weight-bound)",
        "spres": "Megatron-SP residual: sequence-shard the stream so "
        "per-block psums become reduce-scatter+all-gather and f32 "
        "stream tensors shrink 16x per chip (predicted: collective and "
        "memory terms down)",
        "noremat": "remat=block recomputes the forward inside backward "
        "(~+33% FLOPs, ~+fwd HBM); dropping remat trades peak memory for "
        "both terms (predicted: compute/memory down ~25% if it fits)",
        "spdisp_mb4": "compose the two accepted moves",
        "mb4_noremat": "compose microbatch-4 with no-remat",
    }
    for arch, tags in sorted(by.items()):
        if "base" not in tags:
            continue
        base = tags["base"]["roofline"]
        lines += ["", f"### {arch} x train_4k",
                  "",
                  "| variant | hypothesis | t_comp | t_mem | t_coll | "
                  "bound | Δbound | verdict |",
                  "|---|---|---|---|---|---|---|---|"]
        b0 = base["bound_s"]
        lines.append(
            f"| baseline (paper-faithful) | — | {base['t_compute_s']:.3f} "
            f"| {base['t_memory_s']:.3f} | {base['t_collective_s']:.3f} "
            f"| {b0:.3f} | — | dominant={base['dominant']} |")
        for tag, r in sorted(tags.items()):
            if tag == "base":
                continue
            t = r["roofline"]
            gain = (b0 - t["bound_s"]) / b0
            verdict = ("ACCEPT (hypothesis confirmed)" if gain >= 0.02
                       else "backtrack (refuted/neutral)")
            lines.append(
                f"| {tag} | {hypo.get(tag, tag)[:90]} "
                f"| {t['t_compute_s']:.3f} | {t['t_memory_s']:.3f} "
                f"| {t['t_collective_s']:.3f} | {t['bound_s']:.3f} "
                f"| {gain * +100:.1f}% | {verdict} |")

    lines += ["", "### Iteration conclusions (hypothesis log)", """
* **kimi-k2** — baseline 221.4 s bound (traffic). `spdisp` CONFIRMED the
  a2a-replication hypothesis: collective 221->101 s (-54%; predicted ~x16 on
  the dispatch share; measured x2.2 overall because the combine
  reduce-scatter + gradient collectives remain). `mb4` CONFIRMED the
  weight-re-read hypothesis on the memory term (19.7->8.8 s) but the bound
  is traffic-set, so alone it is a backtrack; composed `spdisp+mb4` = 99.9 s
  (2.22x over the paper-faithful baseline). **Next identified move** (from
  the profile's top collectives): the residual 3.4 TB/chip reduce-scatters
  carry f32 payloads ((24,1712,448) x960) — bf16 gradient-collective
  payloads are exactly 2x fewer bytes, predicting bound ~55 s; landing it
  requires dtype-pinning the MoE backward cotangents (left as the next
  iteration; <5%-rule not yet hit).
* **olmoe** — same shape of result: `spdisp` -55% on the bound
  (16.6->7.4 s, CONFIRMED); `mb4` neutral on the traffic-set bound
  (REFUTED for this cell — weight traffic is not the binding term at 7 B
  params); composition adds nothing (stop: two consecutive <5% moves).
* **gemma2** — baseline 2.45 s (traffic: f32 stream psum pairs from the
  context-parallel attention backward). `mb4` -16% and `noremat` -17%
  ACCEPTED (fewer scan iterations -> fewer fixed-size per-microbatch
  collectives; no remat removes the recompute's collectives too);
  `spres` (Megatron-SP) -2% ~neutral at microbatch 1/chip (its win is
  activation memory, not wire bytes) — backtracked. Composed mb4+noremat
  is the accepted end state.

**Stop rule** (paper §VI-B analog): iteration ends when every candidate
move on the dominant term regresses or gains <5% twice in a row.

**Paper-faithful vs beyond-paper.** The baselines above ARE the
paper-faithful configuration (naive replicated MoE dispatch, uniform
microbatching, remat everywhere). Every accepted move is a beyond-paper
optimization discovered by the floorline-style loop the paper prescribes —
recorded separately per row so both are visible."""]
    return "\n".join(lines)


def figures_section():
    p = os.path.join(ROOT, "benchmarks", "results.json")
    if not os.path.exists(p):
        return "## §Paper figures\n\n(run `python -m benchmarks.run`)"
    res = json.load(open(p))
    lines = ["## §Paper-figure reproductions (neuromorphic simulator)", ""]

    ws = res.get("fig2_3_weight_sparsity", {})
    if ws:
        lines += [
            "**Fig 2/3 (weight sparsity).** CNN runtime spread across a "
            "0->0.9 weight-sparsity sweep: "
            f"AKD1000 {ws['cnn']['akd1000_time_spread'] * 100:.1f}%, "
            f"PilotNet/Loihi2 "
            f"{ws['cnn']['pilotnet-loihi2_time_spread'] * 100:.1f}% "
            "(paper: ~0 — dense formats cannot exploit CNN weight "
            "sparsity). S5 linear net: "
            f"{ws['s5']['speedup_0.9_sparsity']:.2f}x at 0.9 sparsity "
            "(paper: ~linear).", ""]
    wf = res.get("fig4_weight_format", {})
    if wf:
        lines += [
            "**Fig 4 (format crossover).** Sparse weight format wins above "
            f"{wf['pilotnet-cnn']['crossover_sparsity']} sparsity for the "
            f"CNN vs {wf['s5-linear']['crossover_sparsity']} for the "
            "linear net (paper: ~0.7 vs ~0.2 — small kernel fetches make "
            "decode overhead dominate for CNNs).", ""]
    ac = res.get("fig5_act_schedules", {})
    if ac:
        worst = max(ac["same_total_time_ratio"].items(), key=lambda kv: kv[1])
        lines += [
            "**Fig 5 (M0).** corr(time, total density): uniform "
            f"{ac['corr']['uniform']:+.3f}; at the SAME total sparsity, "
            f"imbalanced schedules differ up to {worst[1]:.2f}x in time — "
            "network-wide sparsity is an unreliable proxy.", ""]
    ms = res.get("fig6_max_synops", {})
    if ms:
        lines += [
            "**Fig 6 (M1).** Across "
            f"{ms['n_points']} sparsity/balance configs, corr(time, max "
            f"per-core synops) = {ms['mem_region_corr']:+.4f} in the "
            f"memory region; corr(energy, max synops) = "
            f"{ms['energy_corr']:+.4f} (paper: linear boundary + floor).",
            ""]
    cf = res.get("fig7_compute_floor", {})
    if cf:
        lines += [
            "**Fig 7 (M2).** Partitioning the compute-bottleneck layer "
            f"lowers the floor {cf['floor_drop']:.2f}x while energy rises "
            f"{cf['energy_rise']:.2f}x (paper: floor down, power up).", ""]
    tm = res.get("fig8_traffic_mapping", {})
    if tm:
        sp = [f"{r['speedup']:.2f}x" for r in tm["rows"]]
        lines += [
            "**Fig 8 (M3).** Strided vs ordered mapping under high "
            f"utilization: speedups {', '.join(sp)}; never hurts: "
            f"{tm['always_helps']} (paper: helps in all cases).", ""]
    s1 = res.get("fig10_11_stage1", {})
    if s1:
        sp = s1["iso_speedups"]
        lines += [
            "**Fig 10/11 (stage 1).** Iso-accuracy deployed speedups: "
            f"AKD1000+Tl1 {sp['akd1000']:.2f}x (paper 4.29x), Speck+synops "
            f"{sp['speck']:.2f}x (paper 1.01x), PilotNet per-layer Σ-Δ "
            f"targets {sp['pilotnet']:.2f}x (paper 2.23x, same mechanism: "
            "load-balance, imbalance 1.69->1.24 here), S5 pruning "
            f"{sp['s5']:.2f}x (paper 1.74x).", ""]
    s2 = res.get("fig12_stage2", {})
    if s2:
        lines += [
            "**Fig 12 / §VII-C (stage 2 + combined).** S5: stage-2 "
            f"{s2['s5']['stage2_speedup']:.2f}x (paper 1.83x), combined "
            f"{s2['s5']['combined_speedup']:.2f}x time / "
            f"{s2['s5']['combined_energy']:.2f}x energy vs the manual "
            "baseline (paper 1.99x/3.38x). PilotNet-like: stage-2 "
            f"{s2['pilotnet']['stage2_speedup']:.2f}x (paper 1.73x), "
            f"combined {s2['pilotnet']['combined_speedup']:.2f}x (paper "
            "3.86x). The optimizer traces the memory slope exactly as in "
            "the paper (iteration logs in benchmarks/results.json).", ""]
    return "\n".join(lines)


def main():
    single = [r for r in _load(DRY, "__pod.json")]
    multi = [r for r in _load(DRY, "__multipod.json")]
    parts = [
        "# EXPERIMENTS",
        "",
        "Artifacts: experiments/dryrun/*.json (+ .hlo.gz), "
        "experiments/perf/*.json, benchmarks/results.json. "
        "Regenerate this file with "
        "`PYTHONPATH=src python experiments/make_experiments_md.py`.",
        "",
        figures_section(),
        dryrun_section(single + multi),
        "",
        roofline_section(single),
        "",
        perf_section(),
    ]
    out = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(out, "w") as f:
        f.write("\n".join(parts) + "\n")
    print(f"wrote {out}: {len(single)} single-pod + {len(multi)} multipod "
          "cells")


if __name__ == "__main__":
    main()
